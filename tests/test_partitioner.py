"""Green partitioner: Eq. 5 cost model + DP partition semantics."""
import numpy as np
import pytest

from repro.configs.base import ConvLayerDef
from repro.configs.cnn_zoo import get_cnn_config
from repro.configs.registry import get_config
from repro.core import costmodel
from repro.core.partitioner import (capacity_weights,
                                    green_weights, partition_cnn,
                                    partition_costs, partition_transformer)


def test_eq5_conv():
    assert costmodel.cnn_layer_cost(ConvLayerDef("conv", 3, 32, 3, 2)) == 3 * 3 * 3 * 32


def test_eq5_linear():
    assert costmodel.cnn_layer_cost(ConvLayerDef("linear", 1280, 1000)) == 1280 * 1000


def test_eq5_others_params_count():
    se = ConvLayerDef("se", 96, 24)
    assert costmodel.cnn_layer_cost(se) == 2 * 96 * 24 + 96 + 24
    assert costmodel.cnn_layer_cost(ConvLayerDef("pool", 128, 128)) == 0.0


def test_partition_covers_all_layers():
    costs = list(np.random.default_rng(0).uniform(1, 10, size=40))
    p = partition_costs(costs, [1.0, 1.0, 1.0])
    assert p.boundaries[0] == 0 and p.boundaries[-1] == 40
    assert all(a < b for a, b in zip(p.boundaries, p.boundaries[1:]))
    assert abs(sum(p.segment_costs) - sum(costs)) < 1e-6


def test_partition_balances_equal_nodes():
    costs = [1.0] * 30
    p = partition_costs(costs, [1.0, 1.0, 1.0])
    assert p.segment_costs == (10.0, 10.0, 10.0)


def test_partition_respects_capacity():
    costs = [1.0] * 30
    p = partition_costs(costs, [2.0, 1.0])
    # 2:1 split
    assert p.segment_costs == (20.0, 10.0)


def test_comm_weight_moves_boundary():
    """Cheap cut points attract boundaries when comm cost matters."""
    costs = [1.0] * 10
    bb = [0.0] + [100.0] * 4 + [0.0] + [100.0] * 4 + [0.0]  # cheap cut at 5
    partition_costs(costs, [1.0, 1.0], bb, comm_weight=0.0)
    p_comm = partition_costs(costs, [1.0, 1.0], bb, comm_weight=1.0)
    assert p_comm.boundaries[1] == 5
    assert abs(sum(p_comm.segment_costs) - 10.0) < 1e-9


def test_green_weights_prefer_low_carbon():
    capacity_weights([1.0, 1.0])
    g = green_weights([1.0, 1.0], [620.0, 380.0], carbon_weight=0.5)
    assert g[1] > g[0]
    # and a full-capacity bias at carbon_weight=0 reduces to capacity
    g0 = green_weights([1.0, 0.5], [620.0, 380.0], carbon_weight=0.0)
    np.testing.assert_allclose(g0 / g0.sum(), cap_norm([1.0, 0.5]))


def cap_norm(c):
    c = np.asarray(c, float)
    return c / c.sum()


def test_partition_cnn_executable():
    cfg = get_cnn_config("mobilenetv2")
    p = partition_cnn(cfg, [1.0, 1.0, 1.0])
    assert p.num_segments == 3
    assert p.boundaries[-1] == len(cfg.layers)


@pytest.mark.parametrize("arch", ["zamba2-2.7b", "gemma3-27b", "arctic-480b"])
def test_partition_transformer(arch):
    cfg = get_config(arch)
    p = partition_transformer(cfg, [1.0, 0.6, 0.4], seq=4096, batch=1)
    assert p.boundaries[-1] == cfg.num_layers
    assert p.num_segments == 3
    # heavier-weighted node gets >= cost share of the lightest
    assert p.segment_costs[0] >= p.segment_costs[2] * 0.5


def test_moe_active_cost_used():
    """Partitioner costs MoE blocks by ACTIVE params (top-k), not total."""
    cfg = get_config("arctic-480b")
    ld = cfg.layer_defs[0]
    total = costmodel.block_params(cfg, ld, active_only=False)
    active = costmodel.block_params(cfg, ld, active_only=True)
    assert active < 0.1 * total
    f = costmodel.block_flops(cfg, ld, seq=1024, batch=1)
    assert f < 2.5 * 1024 * active * 1.2


# ---------------------------------------------------------------------------
# degenerate shapes, node ids, green-weight clamping (regressions)
# ---------------------------------------------------------------------------


def test_partition_zero_nodes_raises():
    with pytest.raises(ValueError):
        partition_costs([1.0, 2.0], [])


def test_partition_single_node_shapes():
    p = partition_costs([1.0, 2.0, 3.0], [1.0])
    assert p.boundaries == (0, 3)
    assert p.segment_costs == (6.0,)
    assert p.comm_bytes == ()
    assert p.node_order == ("0",)
    assert p.num_segments == 1 == len(p.node_order) == len(p.comm_bytes) + 1


def test_partition_fewer_layers_than_nodes_shapes():
    # 2 layers, 4 nodes: only the first two nodes get a segment, and every
    # tuple stays consistent with num_segments
    p = partition_costs([5.0, 5.0], [1.0, 1.0, 1.0, 1.0],
                        node_ids=["a", "b", "c", "d"])
    assert p.boundaries[0] == 0 and p.boundaries[-1] == 2
    assert p.num_segments == 2
    assert p.node_order == ("a", "b")
    assert len(p.segment_costs) == 2 and len(p.comm_bytes) == 1


def test_partition_empty_costs_shapes():
    p = partition_costs([], [1.0, 1.0])
    assert p.boundaries == (0, 0)
    assert p.segment_costs == (0.0,)
    assert p.node_order == ("0",)


def test_partition_node_ids_label_segments():
    p = partition_costs([1.0] * 30, [2.0, 1.0], node_ids=["big", "small"])
    assert p.node_order == ("big", "small")
    with pytest.raises(ValueError):
        partition_costs([1.0] * 30, [2.0, 1.0], node_ids=["only-one"])


def test_partition_front_ends_accept_node_ids():
    p = partition_cnn(get_cnn_config("mobilenetv2"), [1.0, 1.0, 1.0],
                      node_ids=["x", "y", "z"])
    assert p.node_order == ("x", "y", "z")


def test_green_weights_zero_intensity_finite():
    # a zero-carbon node must clamp, not divide to inf/NaN
    w = green_weights([1.0, 1.0, 1.0], [0.0, 100.0, 500.0])
    assert np.all(np.isfinite(w)) and w.sum() == pytest.approx(1.0)
    assert w[0] > w[1] > w[2]          # cleanest grid still wins
    w_all0 = green_weights([2.0, 1.0], [0.0, 0.0])
    assert np.all(np.isfinite(w_all0))
    assert w_all0[0] > w_all0[1]       # degenerates to capacity ordering


# ---------------------------------------------------------------------------
# brute-force DP parity (hypothesis-backed when available)
# ---------------------------------------------------------------------------

try:  # optional extra — see pyproject.toml
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*args, **kwargs):      # no-op stand-ins so the hypothesis
        return lambda f: f           # tests below stay defined once and

    def settings(*args, **kwargs):   # are reported as skipped
        return lambda f: f

    class _NoStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NoStrategies()

requires_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="hypothesis not installed — pip install -e .[test]")


def _brute_force_objective(costs, weights, bb, comm_weight):
    """Enumerate every placement of k-1 cuts; return the minimal
    bottleneck+comm objective with the DP's exact arithmetic (same prefix
    sums, same cap epsilon, comm billed to the segment the cut starts)."""
    import itertools

    L, k = len(costs), len(weights)
    prefix = np.concatenate([[0.0], np.cumsum(np.asarray(costs, float))])
    w = np.asarray(weights, dtype=np.float64)
    share = w / w.sum()
    total = prefix[-1]
    best = np.inf
    for cuts in itertools.combinations(range(1, L), k - 1):
        bounds = (0,) + cuts + (L,)
        val = 0.0
        for s in range(k):
            a, b = bounds[s], bounds[s + 1]
            cap = share[s] * total + 1e-12
            load = (prefix[b] - prefix[a]) / cap
            comm = comm_weight * bb[a] if a > 0 else 0.0
            val = max(val, load + comm)
        best = min(best, val)
    return best


def _dp_objective(p, costs, weights, bb, comm_weight):
    prefix = np.concatenate([[0.0], np.cumsum(np.asarray(costs, float))])
    w = np.asarray(weights, dtype=np.float64)
    share = w / w.sum()
    total = prefix[-1]
    val = 0.0
    for s, (a, b) in enumerate(p.segments()):
        cap = share[s] * total + 1e-12
        comm = comm_weight * bb[a] if a > 0 else 0.0
        val = max(val, (prefix[b] - prefix[a]) / cap + comm)
    return val


@requires_hypothesis
@settings(max_examples=60, deadline=None)
@given(st.data())
def test_dp_matches_brute_force(data):
    L = data.draw(st.integers(3, 9), label="L")
    k = data.draw(st.integers(2, min(4, L)), label="k")
    costs = data.draw(st.lists(st.floats(0.1, 50.0), min_size=L,
                               max_size=L), label="costs")
    weights = data.draw(st.lists(st.floats(0.2, 4.0), min_size=k,
                                 max_size=k), label="weights")
    bb = data.draw(st.lists(st.floats(0.0, 100.0), min_size=L + 1,
                            max_size=L + 1), label="bb")
    comm_weight = data.draw(st.sampled_from([0.0, 0.01, 0.5]),
                            label="comm_weight")
    p = partition_costs(costs, weights, bb, comm_weight)
    got = _dp_objective(p, costs, weights, bb, comm_weight)
    want = _brute_force_objective(costs, weights, bb, comm_weight)
    assert got == pytest.approx(want, rel=1e-12, abs=1e-12)


def test_dp_matches_brute_force_deterministic():
    # always-on version of the property above (fixed seeds)
    rng = np.random.default_rng(0)
    for _ in range(25):
        L = int(rng.integers(3, 10))
        k = int(rng.integers(2, min(5, L + 1)))
        costs = rng.uniform(0.1, 50.0, L)
        weights = rng.uniform(0.2, 4.0, k)
        bb = rng.uniform(0.0, 100.0, L + 1)
        cwt = float(rng.choice([0.0, 0.01, 0.5]))
        p = partition_costs(costs, weights, bb, cwt)
        got = _dp_objective(p, costs, weights, bb, cwt)
        want = _brute_force_objective(costs, weights, bb, cwt)
        assert got == pytest.approx(want, rel=1e-12, abs=1e-12)


def test_dp_tie_determinism():
    # uniform costs + equal weights: many optimal cut placements tie; the
    # DP must return the same boundaries on every run (strict-< keeps the
    # first optimum found in iteration order)
    costs = [1.0] * 12
    runs = {partition_costs(costs, [1.0, 1.0, 1.0],
                            [0.0] * 13, 0.25).boundaries
            for _ in range(10)}
    assert len(runs) == 1
