"""Green partitioner: Eq. 5 cost model + DP partition semantics."""
import numpy as np
import pytest

from repro.configs.base import ConvLayerDef
from repro.configs.cnn_zoo import get_cnn_config
from repro.configs.registry import get_config
from repro.core import costmodel
from repro.core.partitioner import (capacity_weights,
                                    green_weights, partition_cnn,
                                    partition_costs, partition_transformer)


def test_eq5_conv():
    assert costmodel.cnn_layer_cost(ConvLayerDef("conv", 3, 32, 3, 2)) == 3 * 3 * 3 * 32


def test_eq5_linear():
    assert costmodel.cnn_layer_cost(ConvLayerDef("linear", 1280, 1000)) == 1280 * 1000


def test_eq5_others_params_count():
    se = ConvLayerDef("se", 96, 24)
    assert costmodel.cnn_layer_cost(se) == 2 * 96 * 24 + 96 + 24
    assert costmodel.cnn_layer_cost(ConvLayerDef("pool", 128, 128)) == 0.0


def test_partition_covers_all_layers():
    costs = list(np.random.default_rng(0).uniform(1, 10, size=40))
    p = partition_costs(costs, [1.0, 1.0, 1.0])
    assert p.boundaries[0] == 0 and p.boundaries[-1] == 40
    assert all(a < b for a, b in zip(p.boundaries, p.boundaries[1:]))
    assert abs(sum(p.segment_costs) - sum(costs)) < 1e-6


def test_partition_balances_equal_nodes():
    costs = [1.0] * 30
    p = partition_costs(costs, [1.0, 1.0, 1.0])
    assert p.segment_costs == (10.0, 10.0, 10.0)


def test_partition_respects_capacity():
    costs = [1.0] * 30
    p = partition_costs(costs, [2.0, 1.0])
    # 2:1 split
    assert p.segment_costs == (20.0, 10.0)


def test_comm_weight_moves_boundary():
    """Cheap cut points attract boundaries when comm cost matters."""
    costs = [1.0] * 10
    bb = [0.0] + [100.0] * 4 + [0.0] + [100.0] * 4 + [0.0]  # cheap cut at 5
    partition_costs(costs, [1.0, 1.0], bb, comm_weight=0.0)
    p_comm = partition_costs(costs, [1.0, 1.0], bb, comm_weight=1.0)
    assert p_comm.boundaries[1] == 5
    assert abs(sum(p_comm.segment_costs) - 10.0) < 1e-9


def test_green_weights_prefer_low_carbon():
    capacity_weights([1.0, 1.0])
    g = green_weights([1.0, 1.0], [620.0, 380.0], carbon_weight=0.5)
    assert g[1] > g[0]
    # and a full-capacity bias at carbon_weight=0 reduces to capacity
    g0 = green_weights([1.0, 0.5], [620.0, 380.0], carbon_weight=0.0)
    np.testing.assert_allclose(g0 / g0.sum(), cap_norm([1.0, 0.5]))


def cap_norm(c):
    c = np.asarray(c, float)
    return c / c.sum()


def test_partition_cnn_executable():
    cfg = get_cnn_config("mobilenetv2")
    p = partition_cnn(cfg, [1.0, 1.0, 1.0])
    assert p.num_segments == 3
    assert p.boundaries[-1] == len(cfg.layers)


@pytest.mark.parametrize("arch", ["zamba2-2.7b", "gemma3-27b", "arctic-480b"])
def test_partition_transformer(arch):
    cfg = get_config(arch)
    p = partition_transformer(cfg, [1.0, 0.6, 0.4], seq=4096, batch=1)
    assert p.boundaries[-1] == cfg.num_layers
    assert p.num_segments == 3
    # heavier-weighted node gets >= cost share of the lightest
    assert p.segment_costs[0] >= p.segment_costs[2] * 0.5


def test_moe_active_cost_used():
    """Partitioner costs MoE blocks by ACTIVE params (top-k), not total."""
    cfg = get_config("arctic-480b")
    ld = cfg.layer_defs[0]
    total = costmodel.block_params(cfg, ld, active_only=False)
    active = costmodel.block_params(cfg, ld, active_only=True)
    assert active < 0.1 * total
    f = costmodel.block_flops(cfg, ld, seq=1024, batch=1)
    assert f < 2.5 * 1024 * active * 1.2
