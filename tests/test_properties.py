"""Property-based tests (hypothesis) on system invariants.

hypothesis is an optional extra (``pip install -e .[test]``, see
pyproject.toml); on minimal hosts this module skips cleanly.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cluster import EdgeCluster, NodeSpec
from repro.core.partitioner import green_weights, partition_costs
from repro.core.scheduler import MODES, Task, scores, select_node

SET = settings(max_examples=50, deadline=None)


def cluster_from(cpus, mems, intensities):
    nodes = [NodeSpec(f"n{i}", c, m, it)
             for i, (c, m, it) in enumerate(zip(cpus, mems, intensities))]
    c = EdgeCluster(nodes=nodes, host_power_w=142.0)
    c.profile(250.0)
    return c


@SET
@given(
    cpus=st.lists(st.floats(0.1, 4.0), min_size=2, max_size=6),
    intensity=st.lists(st.floats(10.0, 1200.0), min_size=2, max_size=6),
)
def test_scores_bounded(cpus, intensity):
    n = min(len(cpus), len(intensity))
    c = cluster_from(cpus[:n], [1024] * n, intensity[:n])
    task = Task(cpu=0.05, mem_mb=16, base_latency_ms=250.0)
    for stt in c.nodes.values():
        s = scores(stt, task, c.host_power_w)
        assert np.all(s >= 0.0) and np.all(s <= 1.0)


@SET
@given(intensities=st.lists(st.floats(10.0, 1200.0), min_size=3, max_size=3,
                            unique=True))
def test_green_mode_picks_lowest_carbon_when_equal_otherwise(intensities):
    """With identical cpu/mem/history, green mode must select (near-)min
    intensity — ties at float resolution may pick either."""
    c = cluster_from([1.0, 1.0, 1.0], [1024] * 3, intensities)
    task = Task(cpu=0.05, mem_mb=16, base_latency_ms=250.0)
    chosen = select_node(c, task, MODES["green"])
    chosen_i = intensities[int(chosen[1:])]
    assert chosen_i <= min(intensities) * (1 + 1e-9) + 1e-9


@SET
@given(
    costs=st.lists(st.floats(0.1, 100.0), min_size=3, max_size=60),
    k=st.integers(2, 4),
)
def test_partition_is_exact_cover(costs, k):
    if len(costs) < k:
        return
    p = partition_costs(costs, [1.0] * k)
    assert p.boundaries[0] == 0
    assert p.boundaries[-1] == len(costs)
    assert list(p.boundaries) == sorted(set(p.boundaries))
    assert abs(sum(p.segment_costs) - sum(costs)) < 1e-6 * max(1, sum(costs))


@SET
@given(
    cpus=st.lists(st.floats(0.2, 2.0), min_size=2, max_size=5),
    scale=st.floats(1.1, 5.0),
)
def test_green_weights_monotone_in_intensity(cpus, scale):
    """Raising one node's carbon intensity never raises its green weight."""
    n = len(cpus)
    base_i = [500.0] * n
    w0 = green_weights(cpus, base_i)
    hi = list(base_i)
    hi[0] *= scale
    w1 = green_weights(cpus, hi)
    assert w1[0] / w1.sum() <= w0[0] / w0.sum() + 1e-12


@SET
@given(seed=st.integers(0, 2**31 - 1), t=st.integers(4, 64),
       k=st.integers(2, 4))
def test_moe_routing_properties(seed, t, k):
    """Router invariants: weights positive, sum to 1, indices valid+unique."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import MoEConfig
    from repro.configs.registry import reduced_config
    from repro.models import moe as moe_mod

    cfg = reduced_config("qwen2-moe-a2.7b")
    cfg = cfg.with_overrides(moe=MoEConfig(num_experts=8, top_k=k, expert_ff=64))
    key = jax.random.PRNGKey(seed)
    router_w = jax.random.normal(key, (cfg.d_model, 8)) * 0.5
    x = jax.random.normal(jax.random.fold_in(key, 1), (t, cfg.d_model))
    w, idx, aux = moe_mod.route(cfg, router_w, x)
    assert w.shape == (t, k) and idx.shape == (t, k)
    assert bool(jnp.all(w >= 0))
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, atol=1e-5)
    assert bool(jnp.all((idx >= 0) & (idx < 8)))
    # top-k indices unique per token
    srt = np.sort(np.asarray(idx), axis=1)
    assert np.all(srt[:, 1:] != srt[:, :-1])
    assert float(aux) >= 0.0


@SET
@given(seed=st.integers(0, 2**31 - 1))
def test_moe_combine_conserves_without_drops(seed):
    """With generous capacity, every token's output is a convex combination
    of expert outputs — identity experts must return the input."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import MoEConfig
    from repro.configs.registry import reduced_config
    from repro.models import moe as moe_mod, transformer

    cfg = reduced_config("qwen2-moe-a2.7b").with_overrides(
        moe=MoEConfig(num_experts=4, top_k=2, expert_ff=32,
                      num_shared_experts=0))
    params = transformer.init_params(cfg, jax.random.PRNGKey(seed))
    p = jax.tree.map(lambda a: a[0], params["pattern"]["0"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 16, cfg.d_model)) * 0.3
    y, aux = moe_mod.moe_forward(cfg, p, x)
    assert y.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(y)))


@SET
@given(seed=st.integers(0, 1000), split=st.integers(1, 52))
def test_cnn_split_execution_equivalence(seed, split):
    """forward_range composition == forward, at any boundary."""
    import jax
    import jax.numpy as jnp

    from repro.configs.cnn_zoo import get_cnn_config
    from repro.models import cnn

    cfg = get_cnn_config("mobilenetv2")
    split = min(split, len(cfg.layers) - 1)
    params = cnn.init_params(cfg, jax.random.PRNGKey(seed % 3))
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 32, 32, 3)) * 0.5
    full = cnn.forward(cfg, params, x)
    h = cnn.forward_range(cfg, params, x, 0, split)
    out = cnn.forward_range(cfg, params, h, split, len(cfg.layers))
    np.testing.assert_allclose(np.asarray(full), np.asarray(out),
                               atol=1e-5, rtol=1e-5)
