"""Carbon-aware scheduler: Algorithm 1 semantics + paper behaviour claims."""
import numpy as np
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.scheduler import (MODES, Task, run_workload,
                                  score_table, select_node, sweep_weights,
                                  vector_scores)


def fresh(base=254.85):
    c = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    c.profile(base)
    return c


TASK = Task(cpu=0.1, mem_mb=64, base_latency_ms=254.85)


def test_table1_weights_sum():
    for mode, w in MODES.items():
        assert abs(sum(w.as_array()) - 1.0) < 1e-9, mode
    assert MODES["performance"].w_c == 0.05
    assert MODES["green"].w_c == 0.50
    assert MODES["balanced"].w_c == 0.30


def test_scores_in_unit_range():
    c = fresh()
    for node, s in score_table(c, TASK).items():
        assert np.all(s >= 0.0) and np.all(s <= 1.0), (node, s)


def test_s_c_ordering():
    """Eq. 4: the low-carbon node gets the highest S_C."""
    c = fresh()
    tab = score_table(c, TASK)
    assert tab["node-green"][4] > tab["node-medium"][4] > tab["node-high"][4]


def test_mode_selection_matches_table5():
    c = fresh()
    assert select_node(c, TASK, MODES["performance"]) == "node-high"
    assert select_node(c, TASK, MODES["balanced"]) == "node-high"
    assert select_node(c, TASK, MODES["green"]) == "node-green"


def test_workload_distribution_matches_table5():
    for mode, expect in (("performance", "node-high"),
                         ("balanced", "node-high"),
                         ("green", "node-green")):
        r = run_workload(fresh(), TASK, MODES[mode], iterations=50)
        assert r["distribution"][expect] == 100.0, mode


def test_weight_sweep_transition_at_half():
    """Fig. 3: green takeover begins at w_C >= 0.50 (and not before 0.35)."""
    selections = {}
    for w_c in np.arange(0.0, 0.95, 0.05):
        node = select_node(fresh(), TASK, sweep_weights(float(w_c)))
        selections[round(float(w_c), 2)] = node
    transition = min(w for w, n in selections.items() if n == "node-green")
    assert 0.35 <= transition <= 0.55, selections
    assert selections[0.3] == "node-high"      # balanced ~ performance
    assert selections[0.6] == "node-green"


def test_load_filter():
    """Algorithm 1 line 3: load > 0.8 excludes a node."""
    c = fresh()
    c.nodes["node-high"].load = 0.9
    assert select_node(c, TASK, MODES["performance"]) != "node-high"


def test_latency_threshold_filter():
    c = fresh()
    c.nodes["node-green"].avg_time_ms = 10_000.0
    assert select_node(c, TASK, MODES["green"]) != "node-green"


def test_insufficient_resources():
    c = fresh()
    big = Task(cpu=0.9, mem_mb=64, base_latency_ms=100.0)
    # only node-high has 1.0 cpu
    assert select_node(c, big, MODES["green"]) == "node-high"
    huge = Task(cpu=2.0, mem_mb=64)
    assert select_node(c, huge, MODES["green"]) is None


def test_vector_scores_matches_loop():
    from repro.core.scheduler import scores

    c = fresh()
    w = MODES["green"]
    feats = []
    for st in c.nodes.values():
        e_est = st.power_w(c.host_power_w) * st.avg_time_ms / 3.6e6
        feats.append([
            st.spec.cpu * (1 - st.load) / TASK.cpu,
            (st.spec.mem_mb - st.mem_used_mb) / TASK.mem_mb,
            st.load, st.avg_time_ms / 1000.0, st.running,
            st.spec.carbon_intensity * e_est,
        ])
    v = vector_scores(np.asarray(feats), w.as_array())
    for i, st in enumerate(c.nodes.values()):
        expect = float(w.as_array() @ scores(st, TASK, c.host_power_w))
        assert abs(v[i] - expect) < 1e-9


def test_carbon_accounting_reduction_band():
    """Green vs monolithic carbon reduction lands in the paper's band."""
    mono = fresh()
    for _ in range(50):
        mono.execute("node-medium", 254.85, distributed=False)
    green = fresh()
    run_workload(green, TASK, MODES["green"], iterations=50)
    red = 1 - (green.totals()["carbon_g_per_inf"]
               / mono.totals()["carbon_g_per_inf"])
    assert 0.15 < red < 0.32, red  # paper: 22.9% (range 14.8-32.2 across models)
