"""Incremental FeatureCache vs fresh ``featurize`` — the parity oracle
contract (DESIGN.md §3): after any supported mutation sequence (placement,
completion, direct NodeState writes, topology changes, defer/requeue
through the engine) the cached columns must reproduce a fresh featurize
bit-for-bit, including partial-coverage provider masking."""
import numpy as np
import pytest

from repro.core.api import (CarbonEdgeEngine, FallbackProvider,
                            StaticProvider, TraceProvider)
from repro.core.cluster import EdgeCluster, NodeSpec, PAPER_NODES
from repro.core.policy import (VectorizedPolicy, WeightedScoringPolicy,
                               featurize, featurize_cached)
from repro.core.scheduler import MODES, Task
from repro.core.temporal import synthetic_trace

from tests.test_policy_parity import random_cluster, random_task


def assert_cache_parity(cluster, tasks, provider=None, now_hour=0.0,
                        thr=5000.0):
    F, names = featurize(cluster, tasks, provider, now_hour, thr)
    Fc, names_c = featurize_cached(cluster.feature_cache(), tasks, provider,
                                   now_hour, thr)
    assert names == names_c
    np.testing.assert_array_equal(F, Fc)


def test_fresh_build_matches_featurize():
    rng = np.random.default_rng(0)
    c = random_cluster(rng, 32)
    tasks = [random_task(rng) for _ in range(5)]
    assert_cache_parity(c, tasks)
    assert_cache_parity(c, tasks, StaticProvider.from_cluster(c), 3.0)


@pytest.mark.parametrize("seed", range(5))
def test_parity_after_randomized_mutation_sequences(seed):
    """Placements/completions (engine.step), direct NodeState pokes, and
    profile() interleave; the cache must track every one O(changed)."""
    rng = np.random.default_rng(seed)
    c = random_cluster(rng, int(rng.integers(4, 24)))
    provider = StaticProvider.from_cluster(c)
    eng = CarbonEdgeEngine(c, mode="green", provider=provider)
    for step in range(12):
        op = rng.integers(0, 4)
        if op == 0:                       # placement/completion via engine
            eng.submit_many([Task(cpu=0.01, mem_mb=1.0)
                             for _ in range(int(rng.integers(1, 4)))])
            try:
                eng.step(now_hour=float(step))
            except RuntimeError:
                pass                      # infeasible: requeued, still a mutation
        elif op == 1:                     # direct state writes
            name = list(c.nodes)[int(rng.integers(0, len(c.nodes)))]
            st = c.nodes[name]
            st.load = float(rng.uniform(0.0, 1.0))
            st.mem_used_mb = float(rng.uniform(0.0, st.spec.mem_mb))
            st.running = int(rng.integers(0, 5))
        elif op == 2:                     # re-profile the whole fleet
            c.profile(float(rng.uniform(50.0, 800.0)))
        else:                             # defer/requeue-like queue churn
            eng.submit(Task(cpu=1e9))     # infeasible
            with pytest.raises(RuntimeError):
                eng.step(now_hour=float(step))
            eng.queue.clear()
        tasks = [random_task(rng) for _ in range(int(rng.integers(1, 5)))]
        assert_cache_parity(c, tasks, provider, now_hour=float(step))


def test_parity_with_partial_coverage_provider():
    """A provider covering only feasible nodes must not be queried for
    masked ones — and the cached path must match featurize exactly."""
    rng = np.random.default_rng(42)
    c = random_cluster(rng, 12)
    task = random_task(rng)
    # overload half the fleet, register intensities only for the rest
    names = list(c.nodes)
    for name in names[::2]:
        c.nodes[name].load = 0.95
    feasible_names = [n for n in names
                      if c.nodes[n].load <= 0.8]
    provider = StaticProvider({n: 500.0 for n in feasible_names})
    assert_cache_parity(c, [task], provider)


def test_partial_coverage_uncovered_feasible_node_raises():
    c = EdgeCluster(nodes=PAPER_NODES)
    c.profile(250.0)
    provider = StaticProvider({"node-high": 600.0})   # others uncovered
    with pytest.raises(KeyError):
        featurize_cached(c.feature_cache(), [Task()], provider)


def test_topology_changes_rebuild():
    c = EdgeCluster(nodes=PAPER_NODES)
    c.profile(250.0)
    cache = c.feature_cache()
    assert cache.n == 3
    c.add_node(NodeSpec("n-new", 1.0, 2048, 100.0))
    c.nodes["n-new"].avg_time_ms = 100.0
    assert c.feature_cache().n == 4
    assert_cache_parity(c, [Task()])
    c.remove_node("node-high")
    assert c.feature_cache().n == 3
    assert_cache_parity(c, [Task()])


def test_invalidate_features_escape_hatch():
    c = EdgeCluster(nodes=PAPER_NODES)
    c.profile(250.0)
    c.feature_cache()
    # unsupported surgery: swap a node's state object wholesale
    from repro.core.cluster import NodeState
    c.nodes["node-high"] = NodeState(spec=c.nodes["node-high"].spec,
                                     load=0.5, avg_time_ms=123.0)
    c.invalidate_features()
    assert_cache_parity(c, [Task()])
    # the rebuild must ADOPT the surgically-inserted state: later direct
    # mutations have to be dirty-tracked like any other node's
    c.nodes["node-high"].load = 0.9
    assert_cache_parity(c, [Task()])


def test_removed_node_late_write_stays_o_changed():
    """A write to a NodeState after remove_node must neither corrupt the
    cache nor demote sync to a full rebuild."""
    c = EdgeCluster(nodes=PAPER_NODES)
    c.profile(250.0)
    c.feature_cache()
    ghost = c.nodes["node-high"]
    c.remove_node("node-high")
    cache = c.feature_cache()                 # rebuild for the new topology
    ghost.completed += 1                      # late completion write
    assert not c._dirty                       # detached: nothing marked
    assert c.feature_cache() is cache
    assert_cache_parity(c, [Task()])


def test_trace_provider_batch_respects_custom_at():
    """A user trace with a 24-entry .values but its OWN .at semantics must
    be sampled through .at — batch must equal scalar bit-for-bit."""
    class StepTrace:
        def __init__(self, values):
            self.values = values              # 24-long, but NOT interpolated

        def at(self, hour):
            return self.values[int(hour) % 24]

    c = EdgeCluster(nodes=PAPER_NODES)
    c.profile(250.0)
    tr = StepTrace(tuple(float(100 + 10 * i) for i in range(24)))
    provider = TraceProvider({"node-high": tr},
                             fallback=StaticProvider.from_cluster(c))
    from repro.core.api import intensity_batch
    hours = np.array([0.25, 7.9, 13.5])
    grid = intensity_batch(provider, ["node-high", "node-green"], hours)
    for s, hr in enumerate(hours):
        assert grid[s, 0] == provider.intensity("node-high", float(hr))
        assert grid[s, 1] == provider.intensity("node-green", float(hr))


def test_static_provider_queried_once_across_steps():
    """TIME_INVARIANT providers are memoized: N queries total, not N per
    step."""
    calls = []

    class CountingStatic(StaticProvider):
        def intensity(self, node, hour=0.0):
            calls.append(node)
            return super().intensity(node, hour)

    c = EdgeCluster(nodes=PAPER_NODES)
    c.profile(250.0)
    provider = CountingStatic({n.name: n.carbon_intensity
                               for n in PAPER_NODES})
    for hour in (0.0, 1.0, 2.0):
        featurize_cached(c.feature_cache(), [Task()], provider, hour)
    assert len(calls) == 3                # one per node, ever


def test_time_varying_provider_requeried_per_hour():
    traces = {n.name: synthetic_trace(n.region, n.carbon_intensity)
              for n in PAPER_NODES}
    provider = TraceProvider(traces)
    c = EdgeCluster(nodes=PAPER_NODES)
    c.profile(250.0)
    for hour in (0.0, 6.0, 13.0):
        assert_cache_parity(c, [Task()], provider, hour)


def test_fallback_provider_parity():
    rng = np.random.default_rng(7)
    c = random_cluster(rng, 8)
    names = list(c.nodes)
    traces = {names[0]: synthetic_trace("r", 400.0)}
    provider = FallbackProvider(TraceProvider(traces),
                                StaticProvider.from_cluster(c))
    assert_cache_parity(c, [random_task(rng) for _ in range(3)],
                        provider, now_hour=9.5)


def test_select_batch_cached_vs_fresh_vs_oracle():
    rng = np.random.default_rng(11)
    c = random_cluster(rng, 64)
    tasks = [random_task(rng) for _ in range(16)]
    w = MODES["green"]
    cached = VectorizedPolicy(backend="numpy", use_cache=True)
    fresh = VectorizedPolicy(backend="numpy", use_cache=False)
    oracle = WeightedScoringPolicy()
    assert (cached.select_batch(c, tasks, w)
            == fresh.select_batch(c, tasks, w)
            == oracle.select_batch(c, tasks, w))


def test_dedup_matches_per_task_selection():
    """Duplicate resource profiles share one scored row — selections must
    equal the undeduped per-task path."""
    rng = np.random.default_rng(13)
    c = random_cluster(rng, 16)
    base = [random_task(rng) for _ in range(3)]
    tasks = [base[i % 3] for i in range(12)]        # heavy duplication
    w = MODES["balanced"]
    cached = VectorizedPolicy(backend="numpy")
    batch = cached.select_batch(c, tasks, w)
    singles = [cached.select(c, t, w) for t in tasks]
    assert batch == singles


def test_chunked_scoring_matches_unchunked():
    rng = np.random.default_rng(17)
    c = random_cluster(rng, 32)
    tasks = [random_task(rng) for _ in range(24)]   # all-distinct profiles
    w = MODES["green"]
    small = VectorizedPolicy(backend="numpy")
    small._CHUNK_ELEMS = 64                          # force many chunks
    big = VectorizedPolicy(backend="numpy")
    assert (small.select_batch(c, tasks, w)
            == big.select_batch(c, tasks, w))


# ---------------------------------------------------------------------------
# hypothesis-backed randomized sequences (optional extra)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        ops=st.lists(st.tuples(st.integers(0, 2),
                               st.floats(0.0, 1.0),
                               st.floats(0.0, 1.0)),
                     min_size=1, max_size=10),
    )
    def test_hypothesis_mutation_sequences(seed, ops):
        rng = np.random.default_rng(seed)
        c = random_cluster(rng, int(rng.integers(2, 10)))
        provider = StaticProvider.from_cluster(c)
        names = list(c.nodes)
        for kind, a, b in ops:
            name = names[int(a * (len(names) - 1))]
            stt = c.nodes[name]
            if kind == 0:
                stt.load = b
            elif kind == 1:
                stt.mem_used_mb = b * stt.spec.mem_mb
            else:
                stt.avg_time_ms = 50.0 + 900.0 * b
            assert_cache_parity(c, [Task(cpu=0.05, mem_mb=8.0)], provider)
