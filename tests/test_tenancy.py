"""Multi-tenant subsystem (repro.tenancy, DESIGN.md §7).

Covers: registry column semantics (registration, period rollover, ledger
charging), vectorized escalation parity with the scalar ladder, admission
plan semantics (prefix cut, defer-vs-reject, infeasible passthrough),
engine integration (outcomes, deferral parking/resume, batched-vs-scalar
charge parity, mid-batch failure prefix charging), the period-rollover
regression (escalation must see the current period's spend only), the
BudgetedRouter shim's bit-exact parity with the pre-shim implementation
(re-created inline as the oracle), and an allowance-invariant fuzz: no
tenant's single-period spend ever exceeds its allowance by more than one
task's worth of carbon.
"""
import warnings

import numpy as np
import pytest

from repro.core import energy
from repro.core.api import CarbonEdgeEngine, NoFeasibleNodeError
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.energy import RooflineTerms
from repro.core.router import GreenRouter, PodSpec
from repro.core.scheduler import MODES, Task
from repro.tenancy import (ADMIT, DEFER, REJECT, MODE_ORDER, SLOClass,
                           TenantPolicy, TenantRegistry, TenantSpec,
                           TenantTask)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # optional extra: pip install -e .[test]
    HAVE_HYPOTHESIS = False


def fresh_cluster():
    c = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    c.profile(250.0)
    return c


def task_g(cluster, node="node-green", base_ms=250.0):
    """Exact carbon one task bills on `node` (greenest by default)."""
    _, e = cluster.latency_energy(np.array([base_ms]))
    return float(e[0] * cluster.nodes[node].spec.carbon_intensity
                 * cluster.pue)


def tenant_engine(specs, batch_execute=True, mode="green"):
    c = fresh_cluster()
    reg = TenantRegistry(specs)
    eng = CarbonEdgeEngine(c, mode=mode, policy=TenantPolicy(registry=reg),
                           batch_execute=batch_execute)
    return eng, reg


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_register_and_ids():
    reg = TenantRegistry([TenantSpec("a", allowance_g=1.0),
                          TenantSpec("b", mode="green", priority=3)])
    assert reg.n == 2 and reg.names == ["a", "b"]
    assert reg.mode_floor.tolist() == [0, 2]
    tasks = [TenantTask(tenant="b"), Task(), TenantTask(tenant="zz")]
    assert reg.ids(tasks).tolist() == [1, -1, -1]
    with pytest.raises(ValueError):
        reg.register(TenantSpec("a"))
    with pytest.raises(ValueError):
        TenantSpec("x", mode="turbo")
    with pytest.raises(ValueError):
        TenantSpec("x", period_hours=0.0)


def test_registry_roll_resets_current_period_only():
    reg = TenantRegistry([TenantSpec("a", allowance_g=1.0, period_hours=2.0),
                          TenantSpec("b", allowance_g=1.0)])  # everlasting
    reg.charge(np.array([0, 1]), np.array([0.4, 0.6]))
    assert reg.spent_g.tolist() == [0.4, 0.6]
    reg.roll(1.9)                      # still period 0
    assert reg.spent_g.tolist() == [0.4, 0.6]
    reg.roll(2.0)                      # boundary: period 1 begins
    assert reg.spent_g.tolist() == [0.0, 0.6]      # inf period never rolls
    assert reg.period_idx.tolist() == [1, 0]
    assert reg.total_carbon_g.tolist() == [0.4, 0.6]
    assert reg.peak_spent_g.tolist() == [0.4, 0.6]
    assert reg.next_period_start()[0] == 4.0


def test_roll_aligns_with_wake_hours_across_float_boundaries():
    """roll() must consider the period rolled at exactly the hour
    next_period_start() hands out as the deferral wake — float division
    lands an ulp short of the multiplied boundary for many (k, period)
    pairs (e.g. 0.29 / 0.01 -> 28.999…), which used to strand woken
    tasks in their exhausted period forever."""
    for period in (0.01, 0.02, 0.07, 0.3):
        reg = TenantRegistry([TenantSpec("a", allowance_g=1.0,
                                         period_hours=period)])
        for k in range(1, 120):
            reg.spent_g[0] = 0.5
            wake = float(reg.next_period_start()[0])
            reg.roll(wake)
            assert int(reg.period_idx[0]) == k, (period, k, wake)
            assert reg.spent_g[0] == 0.0


def test_run_until_resumes_across_float_period_boundary():
    """End-to-end regression for the wake/roll float mismatch: a task
    deferred out of an exhausted period 28 (period_hours=0.01) must run
    in period 29, not re-defer to the same hour forever."""
    eng, reg = tenant_engine([TenantSpec("a", allowance_g=0.007,
                                         period_hours=0.01)])
    reg.period_idx[0] = 28
    reg.spent_g[0] = 0.007             # period 28 exhausted
    eng.submit(TenantTask(cpu=0.05, mem_mb=16.0, tenant="a"))
    rep = eng.run_until(0.4, start_hour=0.285)
    assert rep["tenants"]["a"]["completed"] == 1
    assert not eng.deferred and not eng.queue


def test_registry_charge_matches_scalar_fold():
    reg = TenantRegistry([TenantSpec("a"), TenantSpec("b")])
    rng = np.random.default_rng(5)
    carbons = rng.uniform(0.0, 0.3, 64)
    tids = rng.integers(-1, 2, 64)
    reg.charge(tids, carbons)
    want_a = want_b = 0.0
    for t, c in zip(tids, carbons):
        if t == 0:
            want_a += c
        elif t == 1:
            want_b += c
    assert reg.spent_g[0] == want_a and reg.spent_g[1] == want_b
    assert reg.completed.tolist() == [int(np.sum(tids == 0)),
                                      int(np.sum(tids == 1))]


def test_escalation_matches_scalar_ladder():
    reg = TenantRegistry([TenantSpec("a"), TenantSpec("g", mode="green")])
    pol = TenantPolicy(registry=reg)

    def scalar_mode(util):             # the BudgetedRouter ladder, verbatim
        for frac, mode in ((0.5, "performance"), (0.8, "balanced"),
                           (1.01, "green")):
            if util < frac:
                return mode
        return "green"

    utils = np.r_[np.random.default_rng(0).uniform(0, 1.4, 200),
                  [0.0, 0.5, 0.8, 1.0, 1.01]]
    modes = pol._modes_from_util(utils, np.zeros(utils.size, np.int64))
    for u, m in zip(utils, modes):
        assert MODE_ORDER[m] == scalar_mode(u)
    # the green-preference tenant is floored at green regardless of util
    floored = pol._modes_from_util(np.array([0.0]), np.array([1]))
    assert MODE_ORDER[floored[0]] == "green"


# ---------------------------------------------------------------------------
# admission plan semantics
# ---------------------------------------------------------------------------


def test_plan_prefix_cut_and_wake():
    eng, reg = tenant_engine(
        [TenantSpec("a", allowance_g=1.0, period_hours=2.0)])
    c = eng.cluster
    g = task_g(c)
    reg.spent_g[0] = 1.0 - 2.5 * g     # room for exactly 2 more greenest
    pol = eng.policy
    tasks = [TenantTask(cpu=0.05, mem_mb=16.0, tenant="a")
             for _ in range(5)]
    plan = pol.plan(c, tasks, provider=eng.provider, now_hour=0.0)
    assert plan.actions.tolist() == [ADMIT, ADMIT, DEFER, DEFER, DEFER]
    assert np.all(plan.wake_hour[2:] == 2.0)
    assert reg.admitted[0] == 2 and reg.deferred[0] == 3


def test_plan_rejects_when_defer_cannot_help():
    # task pricier than the whole allowance, and a reject-only tenant
    eng, reg = tenant_engine(
        [TenantSpec("tiny", allowance_g=1e-9, period_hours=1.0),
         TenantSpec("strict", allowance_g=1e-9, period_hours=1.0,
                    defer_over_reject=False)])
    reg.spent_g[:] = 1e-9
    pol = eng.policy
    plan = pol.plan(eng.cluster,
                    [TenantTask(cpu=0.05, mem_mb=16.0, tenant="tiny"),
                     TenantTask(cpu=0.05, mem_mb=16.0, tenant="strict")],
                    provider=eng.provider)
    assert plan.actions.tolist() == [REJECT, REJECT]


def test_plan_untagged_and_infeasible_pass_through():
    eng, _ = tenant_engine([TenantSpec("a", allowance_g=0.0,
                                       period_hours=1.0,
                                       defer_over_reject=False)])
    huge = TenantTask(cpu=1e9, mem_mb=1e9, tenant="a")   # feasible nowhere
    plain = Task(cpu=0.05, mem_mb=16.0)
    plan = eng.policy.plan(eng.cluster, [huge, plain],
                           provider=eng.provider)
    assert plan.actions.tolist() == [ADMIT, ADMIT]
    assert plan.expected_g[0] == 0.0 and plan.greenest[0] == -1
    assert plan.modes[1] == -1         # untagged -> engine default weights


def test_in_batch_mode_escalation():
    # a batch big enough to walk one tenant across both thresholds
    eng, reg = tenant_engine([TenantSpec("a", allowance_g=1.0,
                                         period_hours=10.0)])
    c = eng.cluster
    g = task_g(c)
    n = int(1.0 / g) + 1
    tasks = [TenantTask(cpu=0.05, mem_mb=16.0, tenant="a")
             for _ in range(n)]
    plan = eng.policy.plan(c, tasks, provider=eng.provider)
    util = np.cumsum(np.r_[0.0, plan.expected_g[:-1]])
    stages = np.searchsorted([0.5, 0.8], util, side="right")
    adm = plan.actions == ADMIT
    assert (plan.modes[adm] == stages[adm]).all()
    assert {0, 1, 2} <= set(plan.modes[adm].tolist())


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


def test_engine_outcomes_defer_resume_and_report():
    eng, reg = tenant_engine(
        [TenantSpec("a", allowance_g=0.03, period_hours=1.0),
         TenantSpec("b")])
    tasks = [TenantTask(cpu=0.05, mem_mb=16.0, tenant=t)
             for t in ["a"] * 8 + ["b"] * 2]
    eng.submit_many(tasks)
    res = eng.step(now_hour=0.0)
    kinds = [k for k, _ in eng.last_outcomes]
    n_done = kinds.count("done")
    assert n_done == len(res) and kinds.count("defer") == len(eng.deferred)
    assert reg.spent_g[0] <= 0.03 + 1e-12
    rep = eng.report()
    assert rep["tenants"]["a"]["deferred"] == kinds.count("defer") > 0
    # nothing ripe before the period boundary
    assert eng.pop_ripe(0.5) == []
    parked = len(eng.deferred)
    rep2 = eng.run_until(3.0, start_hour=0.0)
    assert not eng.deferred and not eng.queue
    assert rep2["tenants"]["a"]["completed"] == 8
    assert reg.peak_spent_g[0] <= 0.03 + 1e-12
    assert parked > 0 and rep2["end_hour"] >= 1.0


def test_engine_charge_parity_batched_vs_scalar():
    def run(batch_execute):
        eng, reg = tenant_engine(
            [TenantSpec("a", allowance_g=0.05, period_hours=0.5),
             TenantSpec("g", mode="green"), TenantSpec("s")],
            batch_execute=batch_execute)
        rng = np.random.default_rng(9)
        tenants = ["a", "g", "s", ""]
        for hour in (0.0, 0.2, 0.4, 0.6, 1.1):
            eng.submit_many([
                TenantTask(cpu=float(rng.uniform(0.0, 0.2)),
                           mem_mb=float(rng.uniform(4.0, 64.0)),
                           base_latency_ms=float(rng.uniform(50.0, 400.0)),
                           tenant=tenants[int(rng.integers(0, 4))])
                for _ in range(12)])
            eng.step(now_hour=hour)
        return ([(r.node, r.carbon_g) for r in eng.cluster.log],
                reg.spent_g.tolist(), reg.total_carbon_g.tolist(),
                reg.peak_spent_g.tolist(), reg.admitted.tolist(),
                reg.deferred.tolist(), reg.rejected.tolist(),
                reg.completed.tolist(),
                [(w, t) for w, t in eng.deferred])

    assert run(True) == run(False)


def test_engine_mid_batch_failure_charges_prefix():
    def run(batch_execute):
        eng, reg = tenant_engine([TenantSpec("a", allowance_g=50.0,
                                             period_hours=1.0)],
                                 batch_execute=batch_execute)
        good = TenantTask(cpu=0.05, mem_mb=16.0, tenant="a")
        bad = TenantTask(cpu=1e9, mem_mb=1e9, tenant="a")  # infeasible
        eng.submit_many([good, good, bad, good])
        with pytest.raises(NoFeasibleNodeError) as ei:
            eng.step(now_hour=0.0)
        # two executed+charged, failing task + tail requeued
        assert len(ei.value.executed) == 2
        assert reg.completed[0] == 2 and reg.spent_g[0] > 0
        assert len(eng.queue) == 2
        return reg.spent_g.tolist(), [r.carbon_g for r in eng.cluster.log]

    assert run(True) == run(False)


def test_failure_retry_does_not_double_count_admissions():
    """Requeued-then-retried tasks are re-planned; the admitted counter
    must not inflate per retry."""
    eng, reg = tenant_engine([TenantSpec("a", allowance_g=50.0,
                                         period_hours=1.0)])
    good = TenantTask(cpu=0.05, mem_mb=16.0, tenant="a")
    bad = TenantTask(cpu=1e9, mem_mb=1e9, tenant="a")
    eng.submit_many([good, bad, good])
    for _ in range(3):                  # repeated retries all fail at `bad`
        with pytest.raises(NoFeasibleNodeError):
            eng.step(now_hour=0.0)
        assert reg.admitted[0] == 1     # only the executed task counts
    # drop the poison task; the retry then admits and executes the tail
    assert eng.queue[0] is bad
    eng.queue.pop(0)
    eng.step(now_hour=0.0)
    assert reg.admitted[0] == 2 and reg.completed[0] == 2


def test_admission_failure_requeues_whole_batch():
    """A provider failure DURING admission (before anything is consumed)
    must requeue the entire batch — the tenancy-free path's
    never-silently-lost invariant."""
    class PartialProvider:
        def intensity(self, node, hour=0.0):
            if node == "node-green":
                raise KeyError(node)
            return 500.0

    eng, _ = tenant_engine([TenantSpec("a", allowance_g=1.0,
                                       period_hours=1.0)])
    eng.provider = PartialProvider()
    tasks = [TenantTask(cpu=0.05, mem_mb=16.0, tenant="a")
             for _ in range(3)]
    eng.submit_many(tasks)
    with pytest.raises(KeyError):
        eng.step(now_hour=0.0)
    assert eng.queue == tasks and not eng.cluster.log


def test_run_warns_when_deferred_work_stays_parked():
    """run() freezes the clock, so budget-deferred tasks can never wake
    inside it — it must say so instead of silently dropping them."""
    eng, _ = tenant_engine([TenantSpec("a", allowance_g=0.01,
                                       period_hours=1.0)])
    tasks = [TenantTask(cpu=0.05, mem_mb=16.0, tenant="a")
             for _ in range(5)]
    with pytest.warns(RuntimeWarning, match="budget-deferred"):
        rep = eng.run(tasks)
    assert eng.deferred and rep["tenants"]["a"]["deferred"] > 0


def test_failed_step_still_publishes_consumed_verdicts():
    """A step that raises mid-batch must still surface reject/defer
    verdicts for the tasks it consumed (they are in neither the queue
    nor the results); None marks the requeued admitted tail."""
    eng, reg = tenant_engine(
        [TenantSpec("r", allowance_g=0.0, period_hours=1.0,
                    defer_over_reject=False),
         TenantSpec("a", allowance_g=50.0, period_hours=1.0)])
    reg.spent_g[0] = 1.0               # r: always rejected
    rej = TenantTask(cpu=0.05, mem_mb=16.0, tenant="r")
    bad = TenantTask(cpu=1e9, mem_mb=1e9, tenant="a")
    good = TenantTask(cpu=0.05, mem_mb=16.0, tenant="a")
    eng.submit_many([rej, bad, good])
    with pytest.raises(NoFeasibleNodeError):
        eng.step(now_hour=0.0)
    assert eng.last_outcomes[0] == ("reject", "carbon budget exhausted")
    assert eng.last_outcomes[1] is None and eng.last_outcomes[2] is None
    assert eng.queue == [bad, good]    # only admitted tasks requeue


def test_rollover_regression_mid_batch_escalation():
    """Escalation must see the CURRENT period's spend only: a batch
    arriving after the boundary starts from a clean slate even though
    the previous period nearly exhausted the allowance."""
    eng, reg = tenant_engine([TenantSpec("a", allowance_g=0.05,
                                         period_hours=1.0)])
    g = task_g(eng.cluster)
    t = TenantTask(cpu=0.05, mem_mb=16.0, tenant="a")
    eng.submit_many([t] * int(0.05 / g))
    eng.step(now_hour=0.9)                      # near-exhaust period 0
    assert eng.policy.effective_modes()["a"] == "green"
    stale_spend = reg.spent_g[0]
    assert stale_spend > 0.8 * 0.05
    # batch crossing the boundary: must be planned against fresh budget
    eng.submit_many([t] * 3)
    res = eng.step(now_hour=1.25)
    assert len(res) == 3                        # nothing deferred/rejected
    plan_modes = [k for k, _ in eng.last_outcomes]
    assert plan_modes == ["done"] * 3
    assert reg.period_idx[0] == 1
    assert abs(reg.spent_g[0] - sum(r.carbon_g for r in res)) < 1e-15
    assert eng.policy.effective_modes()["a"] == "performance"


# ---------------------------------------------------------------------------
# BudgetedRouter shim parity (bit-exact vs the pre-shim implementation)
# ---------------------------------------------------------------------------

PODS = [
    PodSpec("pod-high", 256, "coal", 620.0),
    PodSpec("pod-medium", 256, "cn", 530.0),
    PodSpec("pod-green", 256, "hydro", 380.0),
]
TERMS = RooflineTerms(0.010, 0.004, 0.002)


class OldBudgetedRouter:
    """The pre-tenancy BudgetedRouter, verbatim — the parity oracle."""

    _ESCALATION = ((0.5, "performance"), (0.8, "balanced"), (1.01, "green"))

    def __init__(self, router):
        self.router = router
        self.tenants = {}   # name -> dict(allowance, spent, denied, admitted)

    def register_tenant(self, tenant, allowance_g):
        self.tenants[tenant] = {"allowance": allowance_g, "spent": 0.0,
                                "denied": 0, "admitted": 0}

    def _util(self, b):
        return b["spent"] / b["allowance"] if b["allowance"] else 1.0

    def _mode_for(self, b):
        for frac, mode in self._ESCALATION:
            if self._util(b) < frac:
                return mode
        return "green"

    def _remaining(self, b):
        return max(b["allowance"] - b["spent"], 0.0)

    def _expected(self, pod_name, terms):
        pod = self.router.pods[pod_name]
        e = energy.step_energy_kwh(terms, pod.chips, pod.chip_power_w)
        return energy.carbon_g(e, pod.carbon_intensity)

    def admit(self, tenant, terms, task=None):
        b = self.tenants[tenant]
        mode = self._mode_for(b)
        prev = self.router.weights
        self.router.weights = MODES[mode]
        try:
            pod = self.router.route(task)
        finally:
            self.router.weights = prev
        expected = self._expected(pod, terms)
        if expected > self._remaining(b):
            greenest = min(self.router.pods.values(),
                           key=lambda p: p.carbon_intensity)
            expected_g = self._expected(greenest.name, terms)
            if expected_g > self._remaining(b):
                b["denied"] += 1
                return (False, None, mode, expected_g)
            pod, expected = greenest.name, expected_g
        b["admitted"] += 1
        return (True, pod, mode, expected)

    def commit(self, tenant, pod, terms):
        carbon = self.router.commit(pod, terms)
        self.tenants[tenant]["spent"] += carbon
        return carbon


def _mk_shim():
    from repro.core.budget import BudgetedRouter

    router = GreenRouter(PODS, mode="performance")
    router.seed_profile({p.name: TERMS for p in PODS})
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        br = BudgetedRouter(router)
    return br


def _mk_old():
    router = GreenRouter(PODS, mode="performance")
    router.seed_profile({p.name: TERMS for p in PODS})
    old = OldBudgetedRouter(router)
    return old


def test_budgeted_router_shim_parity_bit_exact():
    """Drain two tenants through the shim and the verbatim pre-shim
    implementation: every (admitted, pod, mode, expected) decision and
    every spent/denied/admitted counter must match bit-exactly."""
    shim, old = _mk_shim(), _mk_old()
    for br in (shim, old):
        br.register_tenant("a", 1.0)
        br.register_tenant("b", 50.0)
    rng = np.random.default_rng(17)
    for step in range(40):
        tenant = "a" if rng.uniform() < 0.7 else "b"
        res_s = shim.admit(tenant, TERMS)
        res_o = old.admit(tenant, TERMS)
        assert (res_s.admitted, res_s.pod, res_s.mode) == res_o[:3], step
        assert res_s.expected_carbon_g == res_o[3], step
        if res_s.admitted:
            c_s = shim.commit(tenant, res_s.pod, TERMS)
            c_o = old.commit(tenant, res_o[1], TERMS)
            assert c_s == c_o
        for t in ("a", "b"):
            assert shim.tenants[t].spent_g == old.tenants[t]["spent"]
            assert shim.tenants[t].denied == old.tenants[t]["denied"]
            assert shim.tenants[t].admitted == old.tenants[t]["admitted"]
    # tenant a must have walked the full escalation ladder and been denied
    assert old.tenants["a"]["denied"] > 0


def test_budgeted_router_deprecation_and_views():
    router = GreenRouter(PODS, mode="performance")
    router.seed_profile({p.name: TERMS for p in PODS})
    with pytest.warns(DeprecationWarning):
        from repro.core.budget import BudgetedRouter
        br = BudgetedRouter(router)
    br.register_tenant("a", 10.0)
    br.tenants["a"].spent_g = 8.5           # direct pokes write through
    assert br.policy.registry.spent_g[0] == 8.5
    res = br.admit("a", TERMS)
    assert res.mode == "green" and res.pod == "pod-green"
    rep = br.report()
    assert rep["a"]["utilisation"] == 0.85
    with pytest.raises(KeyError):
        br.admit("nobody", TERMS)


def test_budgeted_router_shim_period_rollover():
    """The shim gains what the original lacked: with a finite period,
    escalation is evaluated against the current period's spend only."""
    br = _mk_shim()
    br.register_tenant("a", 1.0, period_hours=1.0)
    br.tenants["a"].spent_g = 0.9
    assert br.admit("a", TERMS, hour=0.5).mode == "green"
    res = br.admit("a", TERMS, hour=1.5)    # fresh period
    assert res.mode == "performance" and res.admitted
    assert br.tenants["a"].spent_g == 0.0


# ---------------------------------------------------------------------------
# allowance-invariant fuzz
# ---------------------------------------------------------------------------


def _run_allowance_example(allowances, periods, traffic_seed, n_steps):
    specs = [TenantSpec(f"t{i}", allowance_g=a, period_hours=p,
                        slo=SLOClass(latency_s=5.0))
             for i, (a, p) in enumerate(zip(allowances, periods))]
    eng, reg = tenant_engine(specs)
    rng = np.random.default_rng(traffic_seed)
    names = [s.name for s in specs] + [""]
    max_task_g = 0.0
    hour = 0.0
    for _ in range(n_steps):
        batch = []
        for _ in range(int(rng.integers(1, 16))):
            base = float(rng.uniform(20.0, 500.0))
            batch.append(TenantTask(
                cpu=float(rng.uniform(0.0, 0.3)),
                mem_mb=float(rng.uniform(0.0, 128.0)),
                base_latency_ms=base,
                tenant=names[int(rng.integers(0, len(names)))]))
            _, e = eng.cluster.latency_energy(np.array([base]))
            worst_i = max(st.spec.carbon_intensity
                          for st in eng.cluster.nodes.values())
            max_task_g = max(max_task_g, float(e[0]) * worst_i)
        eng.queue[:0] = eng.pop_ripe(hour)
        eng.submit_many(batch)
        eng.step(now_hour=hour)
        capped = np.isfinite(reg.allowance_g)
        assert np.all(reg.peak_spent_g[capped]
                      <= reg.allowance_g[capped] + max_task_g + 1e-9), \
            (reg.peak_spent_g, reg.allowance_g, max_task_g)
        hour += float(rng.uniform(0.0, 0.4))


def test_allowance_never_exceeded_seeded():
    """Deterministic slice of the fuzz domain — runs without hypothesis."""
    rng = np.random.default_rng(33)
    for trial in range(15):
        n = int(rng.integers(1, 5))
        allowances = [float(rng.uniform(1e-4, 0.2)) for _ in range(n)]
        periods = [float(rng.choice([0.25, 0.5, 1.0, np.inf]))
                   for _ in range(n)]
        _run_allowance_example(allowances, periods, trial, n_steps=8)


if HAVE_HYPOTHESIS:
    @st.composite
    def tenant_mix(draw):
        n = draw(st.integers(1, 4))
        allowances = [draw(st.floats(1e-4, 0.2)) for _ in range(n)]
        periods = [draw(st.sampled_from([0.25, 0.5, 1.0, float("inf")]))
                   for _ in range(n)]
        seed = draw(st.integers(0, 2 ** 16))
        return allowances, periods, seed

    @given(tenant_mix())
    @settings(max_examples=40, deadline=None)
    def test_hypothesis_allowance_never_exceeded(mix):
        allowances, periods, seed = mix
        _run_allowance_example(allowances, periods, seed, n_steps=6)
else:
    @pytest.mark.skip(reason="hypothesis not installed — pip install .[test]")
    def test_hypothesis_allowance_never_exceeded():
        pass
