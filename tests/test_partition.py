"""Joint partition+placement subsystem (repro.partition, DESIGN.md §8).

Covers: cut-profile column semantics, the (B, P, N) joint selection's
bit-exact parity with the cut-major scalar oracle (numpy column path) and
its agreement with the fused Pallas reduction — including constructed
exact ties, which must resolve to the lowest flattened (p, n) on every
path — the FeatureCache partition block's recompute-on-data_rev-only
contract, engine integration (effective-latency billing of the offloaded
segment, batched-vs-scalar execute parity), split-conformal calibration
(finite-sample quantile, held-out coverage >= nominal - 3%), provider
interval dispatch, and the risk-bounded deferral invariants: the temporal
planner never defers when the interval lower bound loses to executing
now, and the tenancy gate downgrades DEFER to REJECT only when the wake
window certainly loses.
"""
import numpy as np
import pytest

from repro.core.api import (CarbonEdgeEngine, ForecastProvider,
                            StaticProvider, TraceProvider,
                            intensity_interval_batch)
from repro.core.cluster import EdgeCluster, NodeSpec, PAPER_NODES
from repro.core.policy import VectorizedPolicy, get_cache
from repro.core.scheduler import MODES, Task
from repro.core.temporal import (DeferrableTask, plan_wake_batch,
                                 plan_wake_risk, plan_wake_risk_batch,
                                 synthetic_trace)
from repro.partition import (ConformalProvider, CutProfile, JointDecision,
                             PartitionPolicy, SplitConformal,
                             calibrate_intensity, calibrate_latency,
                             profile_cnn, profile_costs, select_joint_scalar)
from repro.tenancy import (ADMIT, DEFER, REJECT, TenantPolicy,
                           TenantRegistry, TenantSpec, TenantTask)

GREEN = MODES["green"]


def random_cluster(rng, n):
    nodes = [NodeSpec(f"n{i}", float(rng.uniform(0.1, 4.0)),
                      int(rng.integers(64, 2048)),
                      float(rng.uniform(10.0, 1200.0)))
             for i in range(n)]
    c = EdgeCluster(nodes=nodes, host_power_w=142.0)
    c.profile(float(rng.uniform(50.0, 1000.0)))
    for st_ in c.nodes.values():
        st_.load = float(rng.uniform(0.0, 0.9))
        st_.mem_used_mb = float(rng.uniform(0.0, st_.spec.mem_mb * 0.5))
        st_.running = int(rng.integers(0, 4))
    return c


def random_task(rng):
    return Task(cpu=float(rng.uniform(0.01, 1.0)),
                mem_mb=float(rng.uniform(4.0, 256.0)),
                base_latency_ms=float(rng.uniform(50.0, 500.0)))


def random_profile(rng, L=6):
    costs = rng.uniform(1.0, 50.0, L)
    bb = np.append(rng.uniform(1e4, 1e7, L - 1), 0.0)
    return profile_costs(costs, boundary_bytes=bb, name="rand")


# ---------------------------------------------------------------------------
# cut profiles
# ---------------------------------------------------------------------------


def test_profile_columns():
    prof = profile_costs([10.0, 20.0, 30.0, 40.0],
                         boundary_bytes=[100.0, 200.0, 300.0, 0.0])
    assert prof.cuts == (0, 1, 2, 3)
    # cut 0 = full offload: everything remote, no boundary to ship
    rf = prof.remote_frac()
    assert rf[0] == 1.0
    np.testing.assert_allclose(rf, [1.0, 0.9, 0.7, 0.4])
    # comm bytes: the activation crossing boundary c (bb[0] = the model
    # input a full offload ships)
    np.testing.assert_allclose(prof.comm_seconds(100.0),
                               np.array([100.0, 200.0, 300.0, 0.0])
                               / (100.0 * 125000.0))


def test_profile_thinning_keeps_cut_zero():
    L = 100
    rng = np.random.default_rng(0)
    prof = profile_costs(rng.uniform(1, 10, L),
                         boundary_bytes=np.append(
                             rng.uniform(1e5, 1e8, L - 1), 0.0),
                         max_cuts=8)
    assert prof.num_cuts == 8
    assert prof.cuts[0] == 0                       # full offload always kept
    assert list(prof.cuts) == sorted(prof.cuts)    # ascending layer order


def test_profile_cnn_real_model():
    from repro.configs.cnn_zoo import get_cnn_config
    prof = profile_cnn(get_cnn_config("mobilenetv2"))
    assert prof.num_cuts >= 2
    rf = prof.remote_frac()
    # monotone non-increasing (zero-cost layers step flat), and a late
    # cut genuinely keeps most compute local
    assert rf[0] == 1.0 and np.all(np.diff(rf) <= 0) and rf[-1] < 0.5
    assert prof.name == "mobilenetv2"


def test_profile_hashable_for_cache_keys():
    p1 = profile_costs([1.0, 2.0], boundary_bytes=[10.0, 0.0])
    p2 = profile_costs([1.0, 2.0], boundary_bytes=[10.0, 0.0])
    assert hash(p1) == hash(p2) and p1 == p2


# ---------------------------------------------------------------------------
# joint selection parity: scalar oracle vs numpy columns vs Pallas
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("mode", ["green", "balanced", "performance"])
def test_numpy_matches_scalar_oracle_bit_exact(seed, mode):
    rng = np.random.default_rng(seed)
    c = random_cluster(rng, int(rng.integers(3, 40)))
    prof = random_profile(rng)
    prov = StaticProvider.from_cluster(c)
    pol = PartitionPolicy(prof, backend="numpy")
    tasks = [random_task(rng) for _ in range(5)]
    got = pol.decide_batch(c, tasks, MODES[mode], provider=prov)
    for t, d in zip(tasks, got):
        ref = select_joint_scalar(c, t, prof, MODES[mode], provider=prov)
        if ref is None:
            assert d is None
            continue
        assert (d.node, d.cut, d.cut_index) == (ref.node, ref.cut,
                                                ref.cut_index)
        assert d.score == ref.score               # bit-exact, not approx


def test_exact_ties_resolve_to_lowest_p_n():
    # identical nodes x identical cuts -> a (P, N) plane of exact ties;
    # every path must pick flattened argmax position (0, 0)
    nodes = [NodeSpec(f"n{i}", 1.0, 512, 300.0) for i in range(4)]
    c = EdgeCluster(nodes=nodes)
    c.profile(250.0)
    prof = CutProfile("tie", total_cost=100.0, cuts=(0, 1, 2),
                      local_cost=(0.0, 0.0, 0.0),
                      remote_cost=(100.0, 100.0, 100.0),
                      comm_bytes=(0.0, 0.0, 0.0))
    t = Task(cpu=0.1, mem_mb=16.0)
    ref = select_joint_scalar(c, t, prof, GREEN,
                              provider=StaticProvider.from_cluster(c))
    assert (ref.cut_index, ref.node) == (0, "n0")
    for backend in ("numpy", "pallas"):
        d = PartitionPolicy(prof, backend=backend).decide(
            c, t, GREEN, provider=StaticProvider.from_cluster(c))
        assert (d.cut_index, d.node) == (0, "n0"), backend


@pytest.mark.parametrize("seed", range(4))
def test_pallas_interpret_matches_numpy(seed):
    rng = np.random.default_rng(100 + seed)
    c = random_cluster(rng, int(rng.integers(3, 20)))
    prof = random_profile(rng, L=5)
    prov = StaticProvider.from_cluster(c)
    tasks = [random_task(rng) for _ in range(4)]
    dn = PartitionPolicy(prof, backend="numpy").decide_batch(
        c, tasks, GREEN, provider=prov)
    dp = PartitionPolicy(prof, backend="pallas").decide_batch(
        c, tasks, GREEN, provider=prov)
    for a, b in zip(dn, dp):
        if a is None:
            assert b is None
            continue
        # float32 kernel vs float64 columns: argmax agreement is only
        # guaranteed outside ulp-scale score gaps — compare decisions and
        # bound the score drift instead of requiring bit-equality
        if abs(a.score - b.score) > 1e-5:
            assert (a.node, a.cut) == (b.node, b.cut)
        assert b.score == pytest.approx(a.score, rel=1e-5)


def test_infeasible_everywhere_returns_none():
    c = EdgeCluster(nodes=PAPER_NODES)
    c.profile(250.0)
    prof = profile_costs([5.0, 5.0], boundary_bytes=[100.0, 0.0])
    huge = Task(cpu=1e9, mem_mb=1e9)
    for backend in ("numpy", "pallas"):
        pol = PartitionPolicy(prof, backend=backend)
        assert pol.decide(c, huge, GREEN) is None
        assert pol.select(c, huge, GREEN) is None


def test_green_mode_prefers_smaller_remote_on_dirty_grid():
    # one node, dirty grid: green weights should shift the cut toward a
    # smaller offloaded share than performance weights do (less remote
    # energy to multiply with the high intensity)
    c = EdgeCluster(nodes=[NodeSpec("n0", 1.0, 512, 900.0)])
    c.profile(400.0)
    L = 8
    costs = np.full(L, 10.0)
    bb = np.append(np.full(L - 1, 1e4), 0.0)     # cheap uplink
    prof = profile_costs(costs, boundary_bytes=bb)
    prov = StaticProvider.from_cluster(c)
    d_perf = PartitionPolicy(prof, backend="numpy").decide(
        c, Task(), MODES["performance"], provider=prov)
    d_green = PartitionPolicy(prof, backend="numpy").decide(
        c, Task(), GREEN, provider=prov)
    assert d_green.remote_frac <= d_perf.remote_frac


# ---------------------------------------------------------------------------
# feature-cache partition block
# ---------------------------------------------------------------------------


def test_partition_block_caches_on_data_rev():
    rng = np.random.default_rng(7)
    c = random_cluster(rng, 12)
    prof = random_profile(rng)
    pol = PartitionPolicy(prof, backend="numpy", use_select_memo=False)
    prov = StaticProvider.from_cluster(c)
    t = random_task(rng)
    pol.decide(c, t, GREEN, provider=prov)
    cache = get_cache(c)
    blk1 = cache._part_blocks[pol._block_key]
    pol.decide(c, t, GREEN, provider=prov)
    assert cache._part_blocks[pol._block_key] is blk1   # no recompute
    # node mutation bumps data_rev -> block recomputed with fresh times
    c.nodes["n0"].avg_time_ms *= 2.0
    pol.decide(c, t, GREEN, provider=prov)
    blk2 = cache._part_blocks[pol._block_key]
    assert blk2 is not blk1 and blk2[0] > blk1[0]


def test_partition_block_matches_joint_time_energy():
    from repro.partition.policy import joint_time_energy
    rng = np.random.default_rng(11)
    c = random_cluster(rng, 6)
    prof = random_profile(rng)
    pol = PartitionPolicy(prof, backend="numpy")
    pol.decide(c, random_task(rng), GREEN,
               provider=StaticProvider.from_cluster(c))
    cache = get_cache(c)
    t_pn, e_pn = cache.partition_block(pol._block_key, pol._rf, pol._cs)
    rf, cs = prof.remote_frac(), prof.comm_seconds(pol.link_mbps)
    for p in range(prof.num_cuts):
        for j, name in enumerate(cache.names):
            st_ = c.nodes[name]
            t_ref, e_ref = joint_time_energy(
                st_.avg_time_ms / 1000.0, st_.power_w(c.host_power_w),
                rf[p], cs[p])
            assert t_pn[p, j] == t_ref and e_pn[p, j] == e_ref


# ---------------------------------------------------------------------------
# engine integration: effective latency, execute-path parity
# ---------------------------------------------------------------------------


def _engine_pair(prof):
    def mk(batch_execute):
        c = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
        c.profile(254.85)
        return CarbonEdgeEngine(c, mode="green",
                                policy=PartitionPolicy(prof,
                                                       backend="numpy"),
                                batch_execute=batch_execute)
    return mk(True), mk(False)


def test_engine_bills_offloaded_segment_only():
    prof = profile_costs([10.0, 10.0, 10.0, 10.0],
                         boundary_bytes=[1e4, 1e4, 1e4, 0.0])
    eng, _ = _engine_pair(prof)
    t = Task(cpu=0.05, mem_mb=16.0, base_latency_ms=400.0)
    eng.submit(t)
    res = eng.step(now_hour=0.0)[0]
    d = eng.policy.last_decisions[0]
    assert d is not None and d.remote_frac < 1.0
    eff = d.effective_latency_ms(t.base_latency_ms)
    assert eff < t.base_latency_ms
    # the cluster measured the *effective* base, not the full one
    expect = eng.cluster.measured_latency_ms(eff, True)
    assert res.latency_ms == pytest.approx(expect)


def test_engine_execute_paths_bit_identical_with_partition_policy():
    prof = profile_costs([10.0, 20.0, 15.0, 30.0],
                         boundary_bytes=[2e4, 5e4, 1e4, 0.0])
    eng_b, eng_s = _engine_pair(prof)
    rng = np.random.default_rng(3)
    tasks = [random_task(rng) for _ in range(16)]
    for t in tasks:
        eng_b.submit(t)
        eng_s.submit(t)
    rb = eng_b.step(now_hour=0.0)
    rs = eng_s.step(now_hour=0.0)
    assert len(rb) == len(rs) == len(tasks)
    for a, b in zip(rb, rs):
        assert (a.node, a.latency_ms, a.energy_kwh, a.carbon_g) == \
            (b.node, b.latency_ms, b.energy_kwh, b.carbon_g)


def test_execution_latency_hook_shape_guard():
    prof = profile_costs([10.0, 10.0], boundary_bytes=[1e4, 0.0])
    pol = PartitionPolicy(prof, backend="numpy")
    c = EdgeCluster(nodes=PAPER_NODES)
    c.profile(250.0)
    t = Task(cpu=0.05, mem_mb=16.0)
    pol.select_batch(c, [t], GREEN)
    assert pol.execution_latency_ms([t]) is not None
    assert pol.execution_latency_ms([t, t]) is None    # re-grouped batch


# ---------------------------------------------------------------------------
# split-conformal calibration
# ---------------------------------------------------------------------------


def test_split_conformal_quantile_small_cases():
    sc = SplitConformal([1.0, -2.0, 3.0])
    # n=3: k = ceil(4 * 0.5) = 2 -> 2nd smallest |residual|
    assert sc.quantile(0.5) == 2.0
    # k = ceil(4 * 0.9) = 4 > n -> cannot certify
    assert sc.quantile(0.9) == float("inf")
    with pytest.raises(ValueError):
        sc.quantile(1.0)
    with pytest.raises(ValueError):
        SplitConformal([])


def test_split_conformal_heldout_coverage():
    rng = np.random.default_rng(42)
    noise = lambda n: rng.standard_t(df=5, size=n) * 3.0   # noqa: E731
    cal = SplitConformal(noise(500))
    q = cal.quantile(0.9)
    assert np.isfinite(q)
    held = noise(4000)
    coverage = float(np.mean(np.abs(held) <= q))
    assert coverage >= 0.87          # nominal 0.9, 3% finite-sample slack


def test_calibrate_intensity_coverage_on_traces():
    traces = {n.name: synthetic_trace(n.region, n.carbon_intensity,
                                      noise=0.08, seed=i)
              for i, n in enumerate(PAPER_NODES)}
    actual = TraceProvider(traces)
    smooth = {n.name: synthetic_trace(n.region, n.carbon_intensity)
              for n in PAPER_NODES}
    forecast = ForecastProvider(TraceProvider(smooth), smoothing_hours=2.0)
    names = list(traces)
    cal_hours = np.arange(0.0, 24.0, 0.25)          # calibration window
    sc = calibrate_intensity(forecast, actual, names, cal_hours)
    test_hours = np.arange(0.125, 24.0, 0.25)       # held-out offsets
    prov = ConformalProvider(forecast, sc)
    lo, hi = prov.intensity_interval_batch(names, test_hours)
    truth = actual.intensity_batch(names, test_hours)
    coverage = float(np.mean((truth >= lo) & (truth <= hi)))
    assert coverage >= 0.87
    assert np.all(lo >= 0.0)                        # clipped at zero


def test_calibrate_latency_bounds_residuals():
    rng = np.random.default_rng(5)
    pred = rng.uniform(50, 500, 200)
    meas = pred * 1.065 + rng.normal(0, 5.0, 200)
    sc = calibrate_latency(pred, meas)
    lo, hi = sc.interval(100.0, coverage=0.9)
    assert lo < 100.0 < hi
    with pytest.raises(ValueError):
        calibrate_latency([1.0], [1.0, 2.0])


# ---------------------------------------------------------------------------
# provider interval dispatch
# ---------------------------------------------------------------------------


def test_measured_providers_answer_zero_width():
    c = EdgeCluster(nodes=PAPER_NODES)
    names = list(c.nodes)
    sp = StaticProvider.from_cluster(c)
    lo, hi = intensity_interval_batch(sp, names, 3.0)
    np.testing.assert_array_equal(lo, hi)
    traces = {n: synthetic_trace(n, 400.0) for n in names}
    lo, hi = intensity_interval_batch(TraceProvider(traces), names,
                                      np.array([0.0, 6.0]))
    np.testing.assert_array_equal(lo, hi)
    assert lo.shape == (2, 3)


def test_unknown_provider_degrades_to_point_interval():
    class Bare:
        def intensity(self, node, hour=0.0):
            return 123.0
    lo, hi = intensity_interval_batch(Bare(), ["a", "b"], 0.0)
    np.testing.assert_array_equal(lo, [123.0, 123.0])
    np.testing.assert_array_equal(lo, hi)


def test_forecast_provider_conformal_band():
    sp = StaticProvider({"a": 100.0, "b": 200.0})
    fp = ForecastProvider(sp, conformal=SplitConformal(
        np.linspace(-30, 30, 99)))
    q = fp.conformal.quantile(0.9)
    lo, hi = fp.intensity_interval_batch(["a", "b"], 0.0)
    np.testing.assert_allclose(hi - lo, 2 * q)
    assert np.all(lo >= 0.0)


# ---------------------------------------------------------------------------
# risk-bounded deferral: temporal planner
# ---------------------------------------------------------------------------


def _risk_fixture(q, seed=0):
    traces = {n.name: synthetic_trace(n.region, n.carbon_intensity,
                                      solar_dip=0.5, seed=seed)
              for n in PAPER_NODES}
    c = EdgeCluster(nodes=PAPER_NODES)
    c.profile(250.0)
    base = TraceProvider(traces)
    prov = ConformalProvider(base, SplitConformal([q]))  # q certifies at 0.5
    return c, prov


def test_risk_plan_zero_width_defers_into_dip():
    # zero-width interval: risk planning should agree with the point
    # planner's "defer only on strict improvement" into the solar dip
    c, prov = _risk_fixture(0.0)
    t = DeferrableTask(cpu=0.05, mem_mb=16.0, deadline_hours=20.0,
                       duration_hours=0.5)
    wake = plan_wake_risk(prov, c, t, 20.0, coverage=0.5)
    assert wake > 20.0
    point = plan_wake_batch(prov, c, [t], 20.0)[0]
    assert wake == point


def test_risk_plan_wide_interval_never_defers():
    # an interval wider than the whole diurnal swing: no future slot's
    # upper bound can undercut now's lower bound -> execute immediately
    c, prov = _risk_fixture(1e4)
    t = DeferrableTask(cpu=0.05, mem_mb=16.0, deadline_hours=20.0,
                       duration_hours=0.5)
    assert plan_wake_risk(prov, c, t, 20.0, coverage=0.5) == 20.0


@pytest.mark.parametrize("seed", range(5))
def test_risk_plan_acceptance_invariant(seed):
    """A deferral's interval upper bound must strictly beat the best
    slot-0 lower bound — 'never defer when the lower bound loses to
    executing now', verified against raw provider reads."""
    rng = np.random.default_rng(seed)
    c, prov = _risk_fixture(float(rng.uniform(0.0, 200.0)), seed=seed)
    tasks = [DeferrableTask(cpu=0.05, mem_mb=16.0,
                            deadline_hours=float(rng.uniform(0.0, 22.0)),
                            duration_hours=0.5) for _ in range(12)]
    now = float(rng.uniform(0.0, 24.0))
    slot = 0.5
    wakes = plan_wake_risk_batch(prov, c, tasks, now, slot_hours=slot,
                                 coverage=0.5)
    names = list(c.nodes)
    for t, w in zip(tasks, wakes):
        if w == now:
            continue
        lo0, _ = intensity_interval_batch(prov, names, now, coverage=0.5)
        _, hi_w = intensity_interval_batch(prov, names, float(w),
                                           coverage=0.5)
        assert float(np.min(hi_w)) < float(np.min(lo0))
        assert w <= now + t.deadline_hours + 1e-9


# ---------------------------------------------------------------------------
# risk-bounded deferral: tenancy admission gate
# ---------------------------------------------------------------------------


def _broke_tenant_policy(coverage):
    # period budget would cover the task, but it's spent: budget DEFER
    reg = TenantRegistry([TenantSpec("a", allowance_g=1.0,
                                     period_hours=2.0)])
    reg.spent_g[0] = 1.0
    return TenantPolicy(registry=reg, defer_risk_coverage=coverage), reg


def test_tenancy_gate_keeps_defer_on_zero_width():
    c = EdgeCluster(nodes=PAPER_NODES)
    c.profile(250.0)
    pol, _ = _broke_tenant_policy(0.5)
    plan = pol.plan(c, [TenantTask(cpu=0.05, mem_mb=16.0, tenant="a")],
                    provider=StaticProvider.from_cluster(c), now_hour=0.0)
    assert plan.actions.tolist() == [DEFER]   # static: wake == now forever


def test_tenancy_gate_rejects_certainly_worse_wake():
    # intensity certainly rises by the wake hour (narrow interval around a
    # steeply climbing trace): deferral burns deadline for worse carbon
    c = EdgeCluster(nodes=PAPER_NODES)
    c.profile(250.0)

    class Climb:
        def intensity(self, node, hour=0.0):
            return 100.0 + 400.0 * hour

        def intensity_interval_batch(self, names, hours, coverage=0.9):
            h = np.asarray(hours, dtype=float)
            v = 100.0 + 400.0 * h
            grid = (np.broadcast_to(v[..., None],
                                    h.shape + (len(names),)).astype(float)
                    if h.ndim else np.full(len(names), float(v)))
            return grid - 5.0, grid + 5.0

    pol, reg = _broke_tenant_policy(0.9)
    plan = pol.plan(c, [TenantTask(cpu=0.05, mem_mb=16.0, tenant="a")],
                    provider=Climb(), now_hour=0.0)
    # wake = 2.0 -> lo_wake = 895 > hi_now = 105: downgraded
    assert plan.actions.tolist() == [REJECT]
    assert reg.rejected[0] == 1 and reg.deferred[0] == 0
    # gate off: plain budget DEFER
    pol2, _ = _broke_tenant_policy(None)
    plan2 = pol2.plan(c, [TenantTask(cpu=0.05, mem_mb=16.0, tenant="a")],
                      provider=Climb(), now_hour=0.0)
    assert plan2.actions.tolist() == [DEFER]


def test_tenancy_gate_validates_coverage():
    with pytest.raises(ValueError):
        TenantPolicy(defer_risk_coverage=1.5)
