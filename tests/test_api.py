"""CarbonEdgeEngine facade + intensity providers (core/api.py)."""
import numpy as np
import pytest

from repro.core.api import (CarbonEdgeEngine, ForecastProvider,
                            StaticProvider, TraceProvider)
from repro.core.carbon import CarbonMonitor
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.policy import (TemporalPolicy, VectorizedPolicy,
                               WeightedScoringPolicy)
from repro.core.scheduler import MODES, Task, run_workload
from repro.core.temporal import synthetic_trace

TASK = Task(cpu=0.1, mem_mb=64, base_latency_ms=254.85)


def fresh(power=141.3, overhead=0.0674):
    c = EdgeCluster(nodes=PAPER_NODES, host_power_w=power,
                    distribution_overhead=overhead)
    c.profile(254.85)
    return c


# ---------------------------------------------------------------------------
# Providers
# ---------------------------------------------------------------------------


def test_static_provider_from_cluster():
    p = StaticProvider.from_cluster(fresh())
    assert p.intensity("node-green") == 380.0
    assert p.intensity("node-high", hour=13.0) == 620.0   # time-invariant
    with pytest.raises(KeyError):
        p.intensity("nope")


def test_trace_provider_fallback():
    tr = synthetic_trace("hydro-rich", 380.0, solar_dip=0.5)
    p = TraceProvider({"node-green": tr},
                      fallback=StaticProvider.from_cluster(fresh()))
    assert p.intensity("node-green", 13.0) == tr.at(13.0)
    assert p.intensity("node-high", 13.0) == 620.0        # fallback
    with pytest.raises(KeyError):
        TraceProvider({}).intensity("node-high")


def test_forecast_provider_composes():
    tr = synthetic_trace("r", 500.0)
    base = TraceProvider({"n": tr})
    lead = ForecastProvider(base, lead_hours=2.0)
    assert lead.intensity("n", 10.0) == pytest.approx(tr.at(12.0))
    # smoothing flattens the signal toward its mean
    smooth = ForecastProvider(base, smoothing_hours=24.0, samples=49)
    flat = [smooth.intensity("n", h) for h in (0.0, 6.0, 13.0, 19.0)]
    raw = [tr.at(h) for h in (0.0, 6.0, 13.0, 19.0)]
    assert np.std(flat) < np.std(raw)
    # composition: forecast over forecast still answers
    assert ForecastProvider(lead, lead_hours=1.0).intensity("n", 9.0) == \
        pytest.approx(tr.at(12.0))
    w = lead.window("n", 0.0, 4.0, 1.0)
    assert w.shape == (4,)


def test_monitor_reads_provider():
    tr = synthetic_trace("n", 600.0, solar_dip=0.5)
    m = CarbonMonitor(provider=TraceProvider({"n": tr}))
    m.register_region("n")                      # intensity from provider
    hi = m.record_energy("n", 1e-3, hour=19.0)  # evening peak
    lo = m.record_energy("n", 1e-3, hour=13.0)  # solar dip
    assert lo < hi
    assert m.regions["n"].tasks == 2
    # report shows what was actually billed (energy-weighted), not the
    # registration-time snapshot
    assert m.report()["n"]["intensity"] == pytest.approx(
        m.total_carbon_g() / m.total_energy_kwh())


def test_monitor_requires_intensity_without_provider():
    m = CarbonMonitor()
    with pytest.raises(ValueError):
        m.register_region("r")
    m.register_region("r", 500.0)               # classic path still works
    assert m.record_energy("r", 1e-3) == pytest.approx(0.5)


def test_monitor_explicit_registration_pins_intensity():
    """A region registered with an explicit intensity keeps it even when the
    monitor has a provider — and regions outside the provider's coverage
    still bill correctly."""
    tr = synthetic_trace("n", 600.0, solar_dip=0.5)
    m = CarbonMonitor(provider=TraceProvider({"n": tr}))
    m.register_region("n")                      # provider-driven
    m.register_region("extra", 500.0)           # pinned, not in provider
    assert m.record_energy("extra", 1e-3) == pytest.approx(0.5)
    m.register_region("n2", 100.0)              # pinned overrides provider
    assert m.record_energy("n2", 1e-3, hour=19.0) == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


def test_engine_default_policy_is_vectorized():
    eng = CarbonEdgeEngine(fresh())
    assert isinstance(eng.policy, VectorizedPolicy)
    assert eng.report()["policy"] == "vectorized"


def test_engine_mode_ordering():
    """Acceptance: green < balanced <= performance carbon per inference
    through the engine API (paper Fig. 2 / Table II ordering)."""
    carbon = {}
    for mode in ("green", "balanced", "performance"):
        rep = CarbonEdgeEngine(fresh(), mode=mode).run(task=TASK,
                                                       iterations=50)
        carbon[mode] = rep["totals"]["carbon_g_per_inf"]
    assert carbon["green"] < carbon["balanced"] <= carbon["performance"]


def test_engine_matches_legacy_run_workload():
    """The engine (batched vectorized path) reproduces the scalar-loop
    workload exactly on the paper scenario."""
    legacy = run_workload(fresh(), TASK, MODES["green"], iterations=50,
                          policy=WeightedScoringPolicy())
    eng = CarbonEdgeEngine(fresh(), mode="green").run(task=TASK,
                                                      iterations=50)
    assert legacy["distribution"] == eng["distribution"]
    for k, v in legacy["totals"].items():
        assert eng["totals"][k] == pytest.approx(v)


def test_engine_batched_equals_serial_steps():
    one = CarbonEdgeEngine(fresh(), mode="green", batch_size=1).run(
        task=TASK, iterations=20)
    allb = CarbonEdgeEngine(fresh(), mode="green").run(task=TASK,
                                                       iterations=20)
    assert one["distribution"] == allb["distribution"]
    assert one["totals"]["carbon_g_per_inf"] == \
        pytest.approx(allb["totals"]["carbon_g_per_inf"])


def test_engine_bills_monitor_per_region():
    eng = CarbonEdgeEngine(fresh(), mode="green")
    rep = eng.run(task=TASK, iterations=10)
    per = rep["per_region"]
    assert per["node-green"]["tasks"] == 10
    assert per["node-high"]["tasks"] == 0
    # monitor total equals cluster-accounted total (same provider intensity)
    total = sum(r.carbon_g for r in eng.cluster.log)
    assert eng.monitor.total_carbon_g() == pytest.approx(total)


def test_engine_trace_provider_time_varying():
    """Same workload at the solar dip vs the evening ramp emits less carbon
    when intensity flows through a TraceProvider."""
    traces = {n.name: synthetic_trace(n.region, n.carbon_intensity,
                                      solar_dip=0.5) for n in PAPER_NODES}
    def run_at(hour):
        c = fresh()
        provider = TraceProvider(traces,
                                 fallback=StaticProvider.from_cluster(c))
        eng = CarbonEdgeEngine(c, mode="green", provider=provider)
        return eng.run(task=TASK, iterations=10,
                       now_hour=hour)["totals"]["carbon_g_per_inf"]
    assert run_at(13.0) < run_at(19.0)


def test_engine_infeasible_raises_and_requeues():
    """An infeasible task aborts the step but stays queued (with the rest of
    its batch), and the results executed before the failure travel on the
    exception, so the caller can retry after freeing capacity."""
    from repro.core.api import NoFeasibleNodeError

    eng = CarbonEdgeEngine(fresh())
    huge = Task(cpu=50.0, mem_mb=1e9)
    eng.submit(TASK).submit(huge).submit(TASK)
    with pytest.raises(RuntimeError, match="no feasible node") as ei:
        eng.step()
    # first task executed; the infeasible one and its tail are requeued
    assert eng.report()["totals"]["tasks"] == 1
    assert eng.queue == [huge, TASK]
    assert isinstance(ei.value, NoFeasibleNodeError)
    assert len(ei.value.executed) == 1          # the completed TaskResult


def test_requeue_preserves_fifo_order_across_retries():
    """Satellite: after a NoFeasibleNodeError the unexecuted tail keeps its
    FIFO order, later submissions land behind it, and a retry (after the
    operator drops the infeasible task) executes in the original order."""
    from repro.core.api import NoFeasibleNodeError

    t1, t2, t3, t4 = (Task(cpu=0.1, mem_mb=64, base_latency_ms=ms)
                      for ms in (100.0, 200.0, 300.0, 400.0))
    huge = Task(cpu=50.0, mem_mb=1e9)
    eng = CarbonEdgeEngine(fresh(overhead=0.0))
    eng.submit_many([t1, huge, t2, t3])
    with pytest.raises(NoFeasibleNodeError) as ei:
        eng.step()
    assert len(ei.value.executed) == 1
    assert eng.queue == [huge, t2, t3]         # tail order intact
    eng.submit(t4)
    assert eng.queue == [huge, t2, t3, t4]     # new work behind the tail
    eng.queue.remove(huge)                     # operator drops the blocker
    eng.step()
    # cluster log shows the original submission order (identified by
    # base latency; overhead=0 so measured == base)
    assert [r.latency_ms for r in eng.cluster.log] == [100.0, 200.0,
                                                       300.0, 400.0]


def test_fallback_provider_edge_cases():
    """Satellite: FallbackProvider covers primary hits, fallback hits,
    double misses, and chained composition."""
    from repro.core.api import FallbackProvider

    tr = synthetic_trace("a", 100.0)
    p = FallbackProvider(TraceProvider({"a": tr}), StaticProvider({"b": 200.0}))
    assert p.intensity("a", 13.0) == tr.at(13.0)
    assert p.intensity("b") == 200.0
    with pytest.raises(KeyError):
        p.intensity("c")                       # both layers miss
    chained = FallbackProvider(p, StaticProvider({}, default=300.0))
    assert chained.intensity("c") == 300.0     # default catches everything


def test_forecast_window_edge_cases():
    """Satellite: empty window, zero smoothing samples, and partial trace
    coverage through the forecast wrapper."""
    from repro.core.api import FallbackProvider

    tr = synthetic_trace("n", 500.0)
    base = TraceProvider({"n": tr})
    f = ForecastProvider(base)
    assert f.window("n", 5.0, 5.0).shape == (0,)        # empty window
    assert f.window("n", 5.0, 4.0).shape == (0,)        # inverted window
    # samples=0 with smoothing: clamped to a 2-point window, stays finite
    zs = ForecastProvider(base, smoothing_hours=2.0, samples=0)
    assert np.isfinite(zs.intensity("n", 1.0))
    # samples=0 without smoothing: exact pass-through
    assert ForecastProvider(base, samples=0).intensity("n", 3.0) == \
        pytest.approx(tr.at(3.0))
    # partial trace coverage surfaces as KeyError...
    with pytest.raises(KeyError):
        f.window("uncovered", 0.0, 2.0)
    # ...unless the base composes a fallback
    covered = ForecastProvider(
        FallbackProvider(base, StaticProvider({}, default=123.0)))
    np.testing.assert_allclose(covered.window("uncovered", 0.0, 2.0, 0.5),
                               123.0)


def test_engine_ledgers_agree_with_pue():
    """Regression: cluster and monitor must bill with the same PUE."""
    c = EdgeCluster(nodes=PAPER_NODES, host_power_w=141.3, pue=1.5)
    c.profile(254.85)
    eng = CarbonEdgeEngine(c, mode="green")
    eng.run(task=TASK, iterations=5)
    cluster_total = sum(r.carbon_g for r in c.log)
    assert eng.monitor.total_carbon_g() == pytest.approx(cluster_total)


def test_partial_coverage_provider_skips_filtered_nodes():
    """A provider with no entry for a filtered-out node must not fail the
    vectorized path (the scalar oracle never queries filtered nodes)."""
    c = fresh()
    c.nodes["node-high"].load = 0.9          # filtered by Algorithm 1 line 3
    partial = TraceProvider({n: synthetic_trace(n, 400.0)
                             for n in ("node-medium", "node-green")})
    with pytest.raises(KeyError):
        partial.intensity("node-high")       # genuinely uncovered
    mon = CarbonMonitor(provider=partial)
    mon.register_region("node-high", 620.0)  # pin the accounting gap
    eng = CarbonEdgeEngine(c, mode="green", provider=partial, monitor=mon)
    rep = eng.run(task=TASK, iterations=3)   # selection must not KeyError
    assert rep["totals"]["tasks"] == 3


def test_router_partial_provider_falls_back_to_pod_intensity():
    """A router with a partial trace feed keeps working: uncovered pods use
    their own static carbon_intensity (FallbackProvider)."""
    from repro.core.router import GreenRouter, PodSpec

    pods = [PodSpec("pod-high", 256, "coal-heavy", 620.0),
            PodSpec("pod-green", 256, "hydro-rich", 380.0)]
    partial = TraceProvider({"pod-green": synthetic_trace("hy", 380.0)})
    r = GreenRouter(pods, mode="green", provider=partial)
    assert r.provider.intensity("pod-high") == 620.0     # fallback
    for st in r.cluster.nodes.values():
        st.avg_time_ms = 10.0                            # seed history
    assert r.route() == "pod-green"


def test_engine_rejects_miswired_monitor():
    """A monitor wired to a different provider with unpinned regions would
    silently bill from the wrong grid signal — must raise."""
    other = StaticProvider({n.name: 1.0 for n in PAPER_NODES})
    mon = CarbonMonitor(provider=other)
    with pytest.raises(ValueError, match="different"):
        CarbonEdgeEngine(fresh(), monitor=mon)
    # fully pinned regions are sound regardless of the monitor's provider
    mon2 = CarbonMonitor(provider=other)
    for n in PAPER_NODES:
        mon2.register_region(n.name, n.carbon_intensity)
    eng = CarbonEdgeEngine(fresh(), mode="green", monitor=mon2)
    assert eng.run(task=TASK, iterations=2)["totals"]["tasks"] == 2


def test_engine_requeues_on_unexpected_failure():
    """Regression: a provider error mid-step must not lose submitted tasks."""
    bad = StaticProvider({"node-high": 620.0})    # missing two cluster nodes
    eng = CarbonEdgeEngine(fresh(), mode="green",
                           provider=StaticProvider.from_cluster(fresh()))
    eng.provider = bad                            # break it after construction
    eng.submit(TASK).submit(TASK)
    with pytest.raises(KeyError):
        eng.step()
    assert eng.queue == [TASK, TASK]              # nothing silently dropped


def test_temporal_scheduler_rejects_conflicting_slot_hours():
    from repro.core.temporal import TemporalScheduler

    c = fresh()
    with pytest.raises(ValueError, match="conflicting slot_hours"):
        TemporalScheduler(c, {}, MODES["green"], slot_hours=0.25,
                          policy=TemporalPolicy())
    # matching or omitted slot_hours is fine
    s = TemporalScheduler(c, {}, MODES["green"], slot_hours=0.5,
                          policy=TemporalPolicy())
    assert s.slot_hours == 0.5


def test_temporal_policy_backend_keeps_inf_threshold():
    """Forcing a backend must not silently reinstate the 5000 ms latency
    filter the temporal path documents as disabled."""
    p = TemporalPolicy(backend="pallas")
    assert p.scorer.latency_threshold_ms == float("inf")
    with pytest.raises(ValueError, match="conflicting latency_threshold_ms"):
        TemporalPolicy(scorer=VectorizedPolicy(),
                       latency_threshold_ms=float("inf"))
    with pytest.raises(ValueError, match="conflicting backend"):
        TemporalPolicy(scorer=VectorizedPolicy(backend="numpy"),
                       backend="pallas")


def test_temporal_policy_plain_task_respects_carbon_weight():
    """Regression: a plain Task (duration 0) must not neutralize the Eq. 4
    column — TemporalPolicy and the instantaneous policies must agree."""
    c = fresh()
    sel_t = TemporalPolicy().select(c, TASK, MODES["green"])
    sel_v = VectorizedPolicy().select(c, TASK, MODES["green"])
    assert sel_t == sel_v == "node-green"


def test_temporal_policy_partial_coverage_provider():
    """Regression: the slot grid must not query the provider for filtered
    nodes (same partial-coverage guarantee as the instantaneous policies)."""
    from repro.core.temporal import DeferrableTask

    c = fresh()
    c.nodes["node-high"].load = 0.9
    partial = TraceProvider({n: synthetic_trace(n, 400.0)
                             for n in ("node-medium", "node-green")})
    t = DeferrableTask(cpu=0.05, mem_mb=16, deadline_hours=4.0,
                       duration_hours=0.25)
    pl = TemporalPolicy().place(c, t, MODES["green"], partial, now_hour=19.0)
    assert pl is not None and pl.node != "node-high"


def test_engine_accepts_provider_less_monitor():
    """A caller-constructed CarbonMonitor without a provider adopts the
    engine's provider, so both ledgers read the same signal."""
    eng = CarbonEdgeEngine(fresh(), mode="green", monitor=CarbonMonitor())
    rep = eng.run(task=TASK, iterations=3)
    assert rep["per_region"]["node-green"]["tasks"] == 3
    assert rep["per_region"]["node-green"]["intensity"] == pytest.approx(380.0)


def test_engine_ledgers_agree_with_time_varying_provider():
    """Regression: with a TraceProvider and now_hour != 0, the cluster's
    execution ledger and the monitor's per-region ledger must bill the same
    carbon — including through a caller-supplied provider-less monitor."""
    traces = {n.name: synthetic_trace(n.region, n.carbon_intensity,
                                      solar_dip=0.5) for n in PAPER_NODES}
    c = fresh()
    provider = TraceProvider(traces, fallback=StaticProvider.from_cluster(c))
    eng = CarbonEdgeEngine(c, mode="green", provider=provider,
                           monitor=CarbonMonitor())
    eng.run(task=TASK, iterations=5, now_hour=13.0)
    cluster_total = sum(r.carbon_g for r in c.log)
    assert eng.monitor.total_carbon_g() == pytest.approx(cluster_total)


def test_engine_temporal_policy_plugs_in():
    """The TemporalPolicy satisfies the SchedulingPolicy interface and can
    drive the engine for urgent tasks."""
    eng = CarbonEdgeEngine(fresh(), mode="green", policy=TemporalPolicy())
    rep = eng.run(task=TASK, iterations=5)
    assert rep["policy"] == "temporal"
    assert rep["totals"]["tasks"] == 5


def test_sweep_endpoints_reproduce_mode_weights():
    """sweep_weights at the performance mode's own w_C reproduces the mode
    exactly (the non-carbon sum is computed, not hardcoded)."""
    from repro.core.scheduler import sweep_weights

    base = MODES["performance"]
    got = sweep_weights(base.w_c)
    np.testing.assert_allclose(got.as_array(), base.as_array(), atol=1e-12)
    # every sweep point stays normalised
    for w_c in np.arange(0.0, 0.95, 0.05):
        assert abs(sum(sweep_weights(float(w_c)).as_array()) - 1.0) < 1e-9
