"""Shared benchmark machinery for the paper-reproduction tables.

Calibration: the paper's *monolithic* rows (Table II/IV) are empirical host
measurements on their DGX SPARK; we treat (base latency, host power,
distribution overhead) as calibration inputs derived from those rows, and
everything else — scheduling behaviour, node selection, energy/carbon
accounting — is produced by our simulation + scheduler. A ``measured``
mode instead times the real JAX CNN forward on this host.

Derived calibration (paper Table II/IV monolithic rows, I=530 gCO2/kWh):
    P = C * 3.6e6 / (I * T);   overhead = green_latency / mono_latency - 1.
"""
from __future__ import annotations

import time
from typing import Dict

from repro.configs.cnn_zoo import get_cnn_config
from repro.core.api import CarbonEdgeEngine
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.scheduler import MODES, Task, Weights

# model -> (base_latency_ms, host_power_w, distribution_overhead)
CALIBRATION: Dict[str, tuple] = {
    "mobilenetv2": (254.85, 141.3, 0.0674),
    "mobilenetv4": (82.96, 100.7, 0.0159),
    "efficientnet-b0": (116.29, 115.7, 0.0253),
}

MONO_INTENSITY = 530.0  # paper's monolithic runs: average-grid scenario
ITERATIONS = 50         # paper §IV.A.4


def measured_latency_ms(model: str, batch: int = 1, repeats: int = 5) -> float:
    """Real JAX forward latency on this host (measured mode)."""
    import jax
    import jax.numpy as jnp

    from repro.models import cnn

    cfg = get_cnn_config(model)
    params = cnn.init_params(cfg, jax.random.PRNGKey(0))
    x = jnp.ones((batch, 224, 224, 3))
    fwd = jax.jit(lambda p, x: cnn.forward(cfg, p, x))
    fwd(params, x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fwd(params, x).block_until_ready()
    return (time.perf_counter() - t0) / repeats * 1e3


def fresh_cluster(model: str) -> EdgeCluster:
    base, power, overhead = CALIBRATION[model]
    c = EdgeCluster(nodes=PAPER_NODES, host_power_w=power,
                    distribution_overhead=overhead)
    c.profile(base)
    return c


def run_monolithic(model: str) -> Dict:
    """Single-node host execution at average grid intensity."""
    base, power, _ = CALIBRATION[model]
    c = EdgeCluster(nodes=PAPER_NODES, host_power_w=power)
    c.profile(base)
    for _ in range(ITERATIONS):
        c.execute("node-medium", base, distributed=False)
    return {"totals": c.totals(), "distribution": c.distribution()}


def run_weights(model: str, weights: Weights) -> Dict:
    """Run the paper workload through the CarbonEdgeEngine (batched
    vectorized scheduling — the production path, not the scalar oracle)."""
    base, _, _ = CALIBRATION[model]
    engine = CarbonEdgeEngine(fresh_cluster(model), weights=weights)
    return engine.run(task=Task(base_latency_ms=base), iterations=ITERATIONS)


def run_amp4ec(model: str) -> Dict:
    """Prior framework: NSA without the carbon term (w_C = 0)."""
    return run_weights(model, Weights(0.2632, 0.2632, 0.3158, 0.1578, 0.0))


def run_mode(model: str, mode: str) -> Dict:
    return run_weights(model, MODES[mode])


def run_sweep_point(model: str, w_c: float) -> Dict:
    from repro.core.scheduler import sweep_weights

    return run_weights(model, sweep_weights(w_c))


def reduction_vs_mono(model: str, r: Dict, mono: Dict) -> float:
    return 100.0 * (1.0 - r["totals"]["carbon_g_per_inf"]
                    / mono["totals"]["carbon_g_per_inf"])
