"""Roofline table from the dry-run artifacts (results/dryrun/*.json)."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(mesh: str = "16x16"):
    rows = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        d = json.loads(f.read_text())
        if "account" not in d:
            continue
        a = d["account"]
        r = a["roofline"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"],
            "swa_variant": d.get("swa_variant", False),
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "bottleneck": r["bottleneck"],
            "step_time_s": r["step_time_s"],
            "model_flops": a["model_flops"],
            "hlo_flops": a["hlo_flops_total"],
            "flops_ratio": a["model_to_hlo_flops_ratio"],
            "collective_bytes": a["collective_bytes_total"],
            "compile_s": d["full"]["compile_s"],
        })
    return rows


def main():
    rows = load()
    if not rows:
        print("no dry-run artifacts yet (run repro.launch.dryrun --all)")
        return []
    print(f"{'arch':18s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'coll_s':>10s} {'bneck':>10s} {'MF/HLO':>7s}")
    for r in rows:
        v = " (swa)" if r["swa_variant"] else ""
        print(f"{r['arch']:18s} {r['shape']+v:12s} {r['compute_s']:10.4f} "
              f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
              f"{r['bottleneck']:>10s} {r['flops_ratio'] or 0:7.2f}")
    return rows


if __name__ == "__main__":
    main()
