"""Scheduling overhead (paper: 0.03 ms per task, <1% CPU).

Measures (a) the Python NSA loop per task, (b) the vectorised numpy scorer
at fleet scale, (c) the Pallas node-score kernel oracle comparison.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core.scheduler import MODES, Task, select_node, vector_scores


def run():
    c = common.fresh_cluster("mobilenetv2")
    task = Task(base_latency_ms=254.85)
    w = MODES["green"]
    # warm
    for _ in range(10):
        select_node(c, task, w)
    n = 2000
    t0 = time.perf_counter()
    for _ in range(n):
        select_node(c, task, w)
    per_task_ms = (time.perf_counter() - t0) / n * 1e3

    # fleet-scale vectorised scorer
    rng = np.random.default_rng(0)
    feats = np.abs(rng.standard_normal((100_000, 6))).astype(np.float32)
    wv = w.as_array()
    vector_scores(feats[:1], wv)
    t0 = time.perf_counter()
    for _ in range(10):
        vector_scores(feats, wv)
    fleet_us_per_100k = (time.perf_counter() - t0) / 10 * 1e6
    return {"per_task_ms": per_task_ms,
            "paper_per_task_ms": 0.03,
            "vector_100k_nodes_us": fleet_us_per_100k,
            "vector_ns_per_node": fleet_us_per_100k * 1e3 / 100_000}


def main():
    out = run()
    print(f"NSA per-task overhead: {out['per_task_ms']*1e3:.1f} us "
          f"(paper: {out['paper_per_task_ms']*1e3:.0f} us)")
    print(f"vectorised scorer, 100k nodes: {out['vector_100k_nodes_us']:.0f} us "
          f"({out['vector_ns_per_node']:.1f} ns/node)")
    return out


if __name__ == "__main__":
    main()
