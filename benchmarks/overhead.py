"""Scheduling overhead (paper: 0.03 ms per task, <1% CPU).

Measures (a) the scalar-oracle NSA loop per task, (b) the default-policy
single select (the GreenRouter.route() path), (c) the batched
CarbonEdgeEngine selection (one vectorized call for the whole batch),
(d) the vectorised numpy scorer at fleet scale, and (e) the END-TO-END
``CarbonEdgeEngine.step`` — select + execute + bill (DESIGN.md §6) — so
the paper's 0.03 ms/task budget is held by the whole step, not just
selection. The Pallas kernel's oracle comparison lives in
tests/test_kernels.py.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core.api import CarbonEdgeEngine
from repro.core.policy import VectorizedPolicy, WeightedScoringPolicy
from repro.core.scheduler import MODES, Task, vector_scores


def run():
    c = common.fresh_cluster("mobilenetv2")
    task = Task(base_latency_ms=254.85)
    w = MODES["green"]
    oracle = WeightedScoringPolicy()
    # warm
    for _ in range(10):
        oracle.select(c, task, w)
    n = 2000
    t0 = time.perf_counter()
    for _ in range(n):
        oracle.select(c, task, w)
    per_task_ms = (time.perf_counter() - t0) / n * 1e3

    # single-task selection through the default (auto) policy — what
    # GreenRouter.route() runs per request (falls through to the scalar
    # loop on small fleets)
    auto = VectorizedPolicy()
    auto.select(c, task, w)
    t0 = time.perf_counter()
    for _ in range(n):
        auto.select(c, task, w)
    route_select_ms = (time.perf_counter() - t0) / n * 1e3

    # batched engine selection: B tasks x N nodes in one scorer call
    policy = VectorizedPolicy(backend="numpy")
    B = 256
    batch = [task] * B
    policy.select_batch(c, batch, w)
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        policy.select_batch(c, batch, w)
    batch_per_task_ms = (time.perf_counter() - t0) / (reps * B) * 1e3

    # end-to-end engine step (select + execute + bill) on the paper
    # cluster: the production batched-execution default vs the per-task
    # execute loop it replaced
    def step_path(batch_execute: bool) -> float:
        eng = CarbonEdgeEngine(common.fresh_cluster("mobilenetv2"),
                               batch_execute=batch_execute)
        eng.submit_many(batch)
        eng.step()                       # warm (cache + memo)
        best = float("inf")
        for _ in range(reps):
            eng.submit_many(batch)
            t0 = time.perf_counter()
            eng.step()
            best = min(best, time.perf_counter() - t0)
        return best * 1e3 / B

    step_e2e_per_task_ms = step_path(True)
    step_scalar_exec_per_task_ms = step_path(False)

    # fleet-scale vectorised scorer
    rng = np.random.default_rng(0)
    feats = np.abs(rng.standard_normal((100_000, 6))).astype(np.float32)
    wv = w.as_array()
    vector_scores(feats[:1], wv)
    t0 = time.perf_counter()
    for _ in range(10):
        vector_scores(feats, wv)
    fleet_us_per_100k = (time.perf_counter() - t0) / 10 * 1e6
    return {"per_task_ms": per_task_ms,
            "paper_per_task_ms": 0.03,
            "route_select_ms": route_select_ms,
            "engine_batch256_per_task_ms": batch_per_task_ms,
            "engine_step_e2e_per_task_ms": step_e2e_per_task_ms,
            "engine_step_scalar_exec_per_task_ms":
                step_scalar_exec_per_task_ms,
            "vector_100k_nodes_us": fleet_us_per_100k,
            "vector_ns_per_node": fleet_us_per_100k * 1e3 / 100_000}


def main():
    out = run()
    print(f"NSA per-task overhead (scalar oracle): {out['per_task_ms']*1e3:.1f} us "
          f"(paper: {out['paper_per_task_ms']*1e3:.0f} us)")
    print(f"default-policy single select (route path): "
          f"{out['route_select_ms']*1e3:.1f} us")
    print(f"engine batched selection (B=256): "
          f"{out['engine_batch256_per_task_ms']*1e3:.2f} us/task")
    print(f"engine e2e step select+execute+bill (B=256): "
          f"{out['engine_step_e2e_per_task_ms']*1e3:.2f} us/task "
          f"(per-task execute loop: "
          f"{out['engine_step_scalar_exec_per_task_ms']*1e3:.2f} us/task)")
    print(f"vectorised scorer, 100k nodes: {out['vector_100k_nodes_us']:.0f} us "
          f"({out['vector_ns_per_node']:.1f} ns/node)")
    return out


if __name__ == "__main__":
    main()
