# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver.

``us_per_call`` is the per-inference (or per-task) latency of the measured
configuration; ``derived`` is that table's headline metric vs the paper.

``--gate NAME`` instead runs a CI gate (benchmarks/ci_gates.py) with the
exact assertions the workflow uses — see ``python -m benchmarks.ci_gates``.
"""
from __future__ import annotations


def main() -> None:
    from benchmarks import (fig2_tradeoff, fig3_weight_sweep, fleet_scale,
                            obs_overhead, overhead, partition_scale, roofline,
                            sim_serving, table2_carbon_footprint,
                            table4_multi_model, table5_node_distribution,
                            temporal_shifting, tenancy_saturation)

    rows = []

    t2 = table2_carbon_footprint.run()
    rows.append(("table2_green_carbon_reduction",
                 t2["ce-green"]["latency_ms"] * 1e3,
                 f"reduction_pct={t2['ce-green']['reduction_vs_mono_pct']:.1f}"))

    t4 = table4_multi_model.run()
    for model, r in t4.items():
        rows.append((f"table4_{model}", r["green_latency_ms"] * 1e3,
                     f"reduction_pct={r['reduction_pct']:.1f}"))

    t5 = table5_node_distribution.run()
    rows.append(("table5_green_node_share", 0.0,
                 f"green_mode_green_node_pct={t5['green']['node-green']:.0f}"))

    f2 = fig2_tradeoff.run()
    rows.append(("fig2_carbon_efficiency",
                 f2["ce-green"]["latency_ms"] * 1e3,
                 f"improvement_x={f2['improvement_x']:.2f}"))

    f3 = fig3_weight_sweep.run()
    rows.append(("fig3_weight_sweep", 0.0,
                 f"transition_w_c={f3['transition_w_c']}"))

    ov = overhead.run()
    rows.append(("scheduler_overhead_per_task", ov["per_task_ms"] * 1e3,
                 "paper_us=30"))
    rows.append(("scheduler_vectorised_100k_nodes", ov["vector_100k_nodes_us"],
                 f"ns_per_node={ov['vector_ns_per_node']:.1f}"))

    fs = fleet_scale.run()
    top = max(fs["select"], key=lambda r: (r["n_nodes"], r["batch"]))
    rows.append((f"fleet_scale_{top['n_nodes']}n_{top['batch']}b_per_task",
                 top["cached_per_task_ms"] * 1e3,
                 f"speedup_vs_rebuild_x={top['speedup_x']:.0f}"))
    wk = max(fs["plan_wake"], key=lambda r: r["n_nodes"])
    rows.append((f"fleet_scale_plan_wake_{wk['n_nodes']}n",
                 wk["batched_ms"] * 1e3,
                 f"speedup_vs_scalar_x={wk['speedup_x']:.0f}"))
    se = max(fs["step"], key=lambda r: (r["n_nodes"], r["batch"]))
    rows.append((f"fleet_scale_step_e2e_{se['n_nodes']}n_{se['batch']}b",
                 se["batched_per_task_ms"] * 1e3,
                 f"speedup_vs_task_loop_x={se['speedup_x']:.1f}"))

    ts = temporal_shifting.run(deadlines=(16.0,))
    rows.append(("beyond_paper_temporal_shifting", 0.0,
                 f"savings_pct={ts[0]['savings_pct']:.1f}"))

    sim = sim_serving.run()
    acc = next(r for r in sim["deferral"] if r["bias_h"] == 0.0)
    worst = sim["deferral"][-1]
    rows.append(("sim_serving_deferral_accurate", 0.0,
                 f"savings_pct={acc['savings_vs_run_now_pct']:.1f}"))
    rows.append(("sim_serving_forecast_regret", 0.0,
                 f"regret_g_at_{worst['bias_h']:g}h={worst['regret_g']:.4f}"))
    loaded = max((r for r in sim["rate_mode"] if r["mode"] == "green"),
                 key=lambda r: r["rate_per_hour"])
    rows.append(("sim_serving_green_wait_p95",
                 loaded["wait_s_p95"] * 1e6,
                 f"slo_violation_rate={loaded['slo_violation_rate']:.3f}"))

    tn = tenancy_saturation.run()
    ov_t = max(tn["overhead"], key=lambda r: (r["n_nodes"], r["batch"]))
    rows.append((f"tenancy_step_e2e_{ov_t['n_nodes']}n_{ov_t['batch']}b",
                 ov_t["tenancy_per_task_ms"] * 1e3,
                 f"admission_overhead_us={ov_t['admission_overhead_us_per_task']:.2f}"))
    sat = max(tn["saturation"],
              key=lambda r: (r["clients_per_tenant"], -r["allowance_scale"]))
    rows.append(("tenancy_saturation_fairness", 0.0,
                 f"jain={sat['budget_fairness_jain']:.3f}"))

    pt = partition_scale.run()
    pstep = max(pt["step"], key=lambda r: (r["n_nodes"], r["batch"],
                                           r["cuts"]))
    rows.append((f"partition_step_e2e_{pstep['n_nodes']}n_{pstep['batch']}b"
                 f"_{pstep['cuts']}p",
                 pstep["per_task_ms"] * 1e3,
                 f"vs_paper_budget_x={pstep['vs_paper_x']:.2f}"))
    rows.append(("partition_conformal_coverage", 0.0,
                 f"heldout={pt['conformal']['heldout_coverage']:.3f}"))

    ob = obs_overhead.run()
    acc_row = max(ob["rows"], key=lambda r: (r["n_nodes"] == 10_000,
                                             r["n_nodes"], r["batch"]))
    rows.append((f"obs_enabled_step_{acc_row['n_nodes']}n"
                 f"_{acc_row['batch']}b",
                 acc_row["enabled_per_task_ms"] * 1e3,
                 f"overhead_x={acc_row['overhead_x']:.2f}"))

    for r in roofline.load():
        rows.append((f"roofline_{r['arch']}_{r['shape']}",
                     r["step_time_s"] * 1e6,
                     f"bottleneck={r['bottleneck']}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    import argparse

    parser = argparse.ArgumentParser(description="benchmark / CI gate driver")
    parser.add_argument("--gate", default=None,
                        help="run a CI gate from benchmarks.ci_gates "
                             "('overhead', 'fleet', 'sim', 'tenancy', "
                             "'partition', 'obs', 'trend', 'all') instead "
                             "of the benchmark CSV")
    parser.add_argument("--baseline", default=None,
                        help="baseline BENCH_fleet_scale.json for --gate trend")
    cli = parser.parse_args()
    if cli.gate is not None:
        from benchmarks import ci_gates

        ci_gates.main(gate=cli.gate, baseline=cli.baseline)
    else:
        main()
