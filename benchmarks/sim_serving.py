"""Beyond-paper: serving under load dynamics (repro.sim, DESIGN.md §2).

The paper evaluates static 50-inference batches; this benchmark drives the
same engine through the discrete-event simulator and sweeps the axis the
paper cannot express — *time*:

- arrival rate x mode: queueing delay and carbon per task as utilisation
  grows (Poisson arrivals, duck-curve grid);
- forecast error x deferral: deferrable evening workload planned through a
  biased persistence forecast; the ``regret_g`` column is realized carbon
  minus the perfect-forecast oracle's, and must grow monotonically with
  the forecast bias (CarbonCP-style acting-under-uncertainty);
- static parity: a constant-rate arrival process over a StaticProvider
  must reproduce the paper-scenario engine numbers exactly (Table II/IV/V
  are a special case of the simulator, not a separate code path).
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.api import (CarbonEdgeEngine, ForecastProvider,
                            StaticProvider, TraceProvider)
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.scheduler import Task
from repro.core.temporal import DeferrableTask, synthetic_trace
from repro.sim import AsyncEngineDriver, ConstantRateArrivals, PoissonArrivals

EVENING_HOUR = 17.0          # submissions start on the evening ramp
BASE_LATENCY_MS = 250.0
SEED = 7


def duck_traces() -> Dict[str, object]:
    return {
        "node-high": synthetic_trace("coal-heavy", 620.0, solar_dip=0.1),
        "node-medium": synthetic_trace("cn-average", 530.0, solar_dip=0.3),
        "node-green": synthetic_trace("hydro-rich", 380.0, solar_dip=0.5),
    }


def make_engine(mode: str, time_varying: bool = True) -> CarbonEdgeEngine:
    c = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    c.profile(BASE_LATENCY_MS)
    provider = (TraceProvider(duck_traces(),
                              fallback=StaticProvider.from_cluster(c))
                if time_varying else StaticProvider.from_cluster(c))
    return CarbonEdgeEngine(c, mode=mode, provider=provider)


def run_scenario(mode: str, arrivals, *, deferrable_hours: float = 0.0,
                 forecast=None, horizon_hours: float = 2.0,
                 start_hour: float = EVENING_HOUR, max_batch: int = 16,
                 slo_latency_s: float = 2.0) -> Dict:
    engine = make_engine(mode)

    def factory(uid: int, hour: float):
        if deferrable_hours > 0:
            return DeferrableTask(cpu=0.05, mem_mb=16.0,
                                  base_latency_ms=BASE_LATENCY_MS,
                                  deadline_hours=deferrable_hours,
                                  duration_hours=0.25)
        return Task(cpu=0.05, mem_mb=16.0, base_latency_ms=BASE_LATENCY_MS)

    driver = AsyncEngineDriver(engine, arrivals, factory,
                               start_hour=start_hour,
                               horizon_hours=horizon_hours,
                               max_batch=max_batch, forecast=forecast,
                               slo_latency_s=slo_latency_s, tick_hours=1.0)
    m = driver.run()
    return m.summary()


# -- sweep 1: arrival rate x mode -------------------------------------------


def rate_mode_sweep(rates=(2000.0, 8000.0, 12000.0),
                    modes=("green", "performance"),
                    horizon_hours: float = 0.05) -> List[Dict]:
    rows = []
    for mode in modes:
        for rate in rates:
            s = run_scenario(mode, PoissonArrivals(rate, seed=SEED),
                             horizon_hours=horizon_hours)
            rows.append({"mode": mode, "rate_per_hour": rate,
                         "carbon_g_per_task": s["carbon_g_per_task"],
                         "wait_s_p50": s["wait_s_p50"],
                         "wait_s_p95": s["wait_s_p95"],
                         "slo_violation_rate": s["slo_violation_rate"],
                         "wait_histogram": s["wait_histogram"]})
    return rows


# -- sweep 2: forecast error x deferral --------------------------------------


def deferral_sweep(biases=(0.0, 1.0, 2.0, 4.0), rate: float = 60.0,
                   deadline_hours: float = 24.0) -> List[Dict]:
    """Evening-submitted deferrable workload. ``bias`` hours of persistence
    lead on the forecast shifts the planned wake slot off the true solar
    dip; the oracle row is bias 0 (forecast == realized trace)."""
    arrivals = PoissonArrivals(rate, seed=SEED)
    true_provider = TraceProvider(duck_traces())

    run_now = run_scenario("green", arrivals,
                           deferrable_hours=deadline_hours, forecast=None)
    rows = [{"scenario": "run-now", "bias_h": None,
             "carbon_g_total": run_now["carbon_g_total"],
             "deferred_tasks": run_now["deferred_tasks"]}]
    # The oracle is always an explicit bias-0 run (forecast == realized
    # trace), whatever biases the caller sweeps.
    oracle = run_scenario("green", arrivals, deferrable_hours=deadline_hours,
                          forecast=ForecastProvider(true_provider))
    oracle_total = oracle["carbon_g_total"]
    for b in biases:
        s = oracle if b == 0.0 else run_scenario(
            "green", arrivals, deferrable_hours=deadline_hours,
            forecast=ForecastProvider(true_provider, lead_hours=b))
        rows.append({
            "scenario": f"defer(bias={b:g}h)", "bias_h": b,
            "carbon_g_total": s["carbon_g_total"],
            "deferred_tasks": s["deferred_tasks"],
            "savings_vs_run_now_pct": 100.0 * (
                1.0 - s["carbon_g_total"] / run_now["carbon_g_total"]),
            "regret_g": s["carbon_g_total"] - oracle_total,
        })
    return rows


# -- sweep 3: static parity ---------------------------------------------------


def static_parity(iterations: int = 50) -> Dict:
    """The simulator with a constant-rate process and a static provider
    must reproduce the paper-scenario engine run exactly (Table II/IV/V
    numbers are unchanged by the new driver)."""
    ref = CarbonEdgeEngine(
        EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0), mode="green")
    ref.cluster.profile(BASE_LATENCY_MS)
    ref_rep = ref.run(task=Task(cpu=0.05, mem_mb=16.0,
                                base_latency_ms=BASE_LATENCY_MS),
                      iterations=iterations)

    engine = make_engine("green", time_varying=False)
    driver = AsyncEngineDriver(
        engine, ConstantRateArrivals(rate_per_hour=float(iterations)),
        lambda uid, hour: Task(cpu=0.05, mem_mb=16.0,
                               base_latency_ms=BASE_LATENCY_MS),
        start_hour=0.0, horizon_hours=1.0, max_batch=16)
    driver.run()
    sim_rep = engine.report()
    ref_c = ref_rep["totals"]["carbon_g_per_inf"]
    sim_c = sim_rep["totals"]["carbon_g_per_inf"]
    return {
        "ref_carbon_g_per_inf": ref_c,
        "sim_carbon_g_per_inf": sim_c,
        "carbon_match": abs(ref_c - sim_c) < 1e-12,
        "distribution_match": ref_rep["distribution"] == sim_rep["distribution"],
    }


def run() -> Dict:
    return {
        "rate_mode": rate_mode_sweep(),
        "deferral": deferral_sweep(),
        "parity": static_parity(),
    }


def main() -> Dict:
    out = run()
    print(f"{'mode':>12s} {'rate/h':>8s} {'g/task':>9s} {'p50 wait s':>10s} "
          f"{'p95 wait s':>10s} {'slo viol':>8s}")
    for r in out["rate_mode"]:
        print(f"{r['mode']:>12s} {r['rate_per_hour']:8.0f} "
              f"{r['carbon_g_per_task']:9.5f} {r['wait_s_p50']:10.3f} "
              f"{r['wait_s_p95']:10.3f} {r['slo_violation_rate']:8.3f}")
    print(f"\n{'scenario':>16s} {'carbon g':>10s} {'deferred':>8s} "
          f"{'savings %':>9s} {'regret g':>9s}")
    for r in out["deferral"]:
        sav = r.get("savings_vs_run_now_pct")
        reg = r.get("regret_g")
        print(f"{r['scenario']:>16s} {r['carbon_g_total']:10.4f} "
              f"{r['deferred_tasks']:8d} "
              f"{sav if sav is None else format(sav, '9.1f')!s:>9s} "
              f"{reg if reg is None else format(reg, '9.4f')!s:>9s}")
    p = out["parity"]
    print(f"\nstatic parity: carbon_match={p['carbon_match']} "
          f"distribution_match={p['distribution_match']} "
          f"(ref {p['ref_carbon_g_per_inf']:.6f} g/inf, "
          f"sim {p['sim_carbon_g_per_inf']:.6f} g/inf)")
    return out


if __name__ == "__main__":
    main()
