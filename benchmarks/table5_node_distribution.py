"""Paper Table V: node usage distribution per scheduling mode."""
from __future__ import annotations

from benchmarks import common

PAPER = {
    "performance": {"node-high": 100.0, "node-medium": 0.0, "node-green": 0.0},
    "balanced": {"node-high": 100.0, "node-medium": 0.0, "node-green": 0.0},
    "green": {"node-high": 0.0, "node-medium": 0.0, "node-green": 100.0},
}


def run(model: str = "mobilenetv2"):
    return {mode: common.run_mode(model, mode)["distribution"]
            for mode in ("performance", "balanced", "green")}


def main():
    out = run()
    print(f"{'mode':13s} {'node-high':>10s} {'node-medium':>12s} {'node-green':>11s}")
    for mode, dist in out.items():
        print(f"{mode:13s} {dist['node-high']:10.0f} {dist['node-medium']:12.0f} "
              f"{dist['node-green']:11.0f}   (paper: "
              f"{PAPER[mode]['node-high']:.0f}/{PAPER[mode]['node-medium']:.0f}/"
              f"{PAPER[mode]['node-green']:.0f})")
    return out


if __name__ == "__main__":
    main()
