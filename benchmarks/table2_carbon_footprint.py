"""Paper Table II: carbon footprint comparison (MobileNetV2).

Monolithic / AMP4EC / CE-Performance / CE-Balanced / CE-Green.
"""
from __future__ import annotations

from benchmarks import common

PAPER = {  # configuration -> (latency_ms, carbon_g_per_inf)
    "monolithic": (254.85, 0.0053),
    "amp4ec": (277.22, 0.0056),
    "ce-performance": (271.38, 0.0067),
    "ce-balanced": (271.11, 0.0066),
    "ce-green": (272.02, 0.0041),
}


def run(model: str = "mobilenetv2"):
    mono = common.run_monolithic(model)
    rows = {"monolithic": mono,
            "amp4ec": common.run_amp4ec(model),
            "ce-performance": common.run_mode(model, "performance"),
            "ce-balanced": common.run_mode(model, "balanced"),
            "ce-green": common.run_mode(model, "green")}
    out = {}
    for name, r in rows.items():
        t = r["totals"]
        out[name] = {
            "latency_ms": t["avg_latency_ms"],
            "throughput_rps": t["throughput_rps"],
            "carbon_g_per_inf": t["carbon_g_per_inf"],
            "reduction_vs_mono_pct": common.reduction_vs_mono(model, r, mono),
            "paper_latency_ms": PAPER[name][0],
            "paper_carbon": PAPER[name][1],
        }
    return out


def main():
    out = run()
    print(f"{'config':16s} {'lat(ms)':>8s} {'rps':>6s} {'gCO2/inf':>9s} "
          f"{'red%':>7s} {'paper gCO2':>10s}")
    for name, r in out.items():
        print(f"{name:16s} {r['latency_ms']:8.2f} {r['throughput_rps']:6.2f} "
              f"{r['carbon_g_per_inf']:9.5f} {r['reduction_vs_mono_pct']:7.1f} "
              f"{r['paper_carbon']:10.4f}")
    return out


if __name__ == "__main__":
    main()
