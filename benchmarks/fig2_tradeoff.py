"""Paper Fig. 2: latency vs carbon-efficiency trade-off.

Paper claims: CE-Green 245.8 inf/gCO2 vs monolithic 189.5 (1.30x);
CE-Performance 149.6; all CE modes within ~7% latency of monolithic.
"""
from __future__ import annotations

from benchmarks import common

PAPER_EFF = {"monolithic": 189.5, "ce-performance": 149.6, "ce-green": 245.8}


def run(model: str = "mobilenetv2"):
    mono = common.run_monolithic(model)
    rows = {"monolithic": mono,
            "ce-performance": common.run_mode(model, "performance"),
            "ce-balanced": common.run_mode(model, "balanced"),
            "ce-green": common.run_mode(model, "green")}
    out = {}
    for name, r in rows.items():
        t = r["totals"]
        out[name] = {
            "latency_ms": t["avg_latency_ms"],
            "carbon_eff_inf_per_g": t["carbon_efficiency_inf_per_g"],
            "latency_overhead_pct": 100.0 * (t["avg_latency_ms"]
                                             / mono["totals"]["avg_latency_ms"] - 1.0),
        }
    out["improvement_x"] = (out["ce-green"]["carbon_eff_inf_per_g"]
                            / out["monolithic"]["carbon_eff_inf_per_g"])
    return out


def main():
    out = run()
    impr = out.pop("improvement_x")
    print(f"{'config':16s} {'lat(ms)':>8s} {'inf/gCO2':>9s} {'lat ovh%':>9s} {'paper':>7s}")
    for name, r in out.items():
        p = PAPER_EFF.get(name, float('nan'))
        print(f"{name:16s} {r['latency_ms']:8.2f} {r['carbon_eff_inf_per_g']:9.1f} "
              f"{r['latency_overhead_pct']:9.2f} {p:7.1f}")
    print(f"green/mono carbon-efficiency improvement: {impr:.2f}x (paper 1.30x)")
    out["improvement_x"] = impr
    return out


if __name__ == "__main__":
    main()
