"""CI gate assertions, runnable locally with the exact checks CI uses.

Each gate that the workflow (.github/workflows/ci.yml) runs is a plain
function here, so a red CI can be reproduced and debugged from a checkout:

    PYTHONPATH=src:. python -m benchmarks.ci_gates            # all gates
    PYTHONPATH=src:. python -m benchmarks.ci_gates overhead
    PYTHONPATH=src:. python -m benchmarks.ci_gates fleet
    PYTHONPATH=src:. python -m benchmarks.ci_gates sim
    PYTHONPATH=src:. python -m benchmarks.ci_gates tenancy
    PYTHONPATH=src:. python -m benchmarks.ci_gates partition
    PYTHONPATH=src:. python -m benchmarks.ci_gates obs
    PYTHONPATH=src:. python -m benchmarks.ci_gates sim_scale
    PYTHONPATH=src:. python -m benchmarks.ci_gates trend --baseline bench-baseline/

(or ``python -m benchmarks.run --gate NAME`` — same registry.)

Gates:

- **overhead** — scalar oracle under a generous CPU bound; batched engine
  selection AND the end-to-end step (select + execute + bill, DESIGN.md
  §6) under the paper's 0.03 ms/task budget; batched paths faster than
  the per-task loops they replaced.
- **fleet** — reduced fleet-scale sweep: cached selection >3x over the
  rebuild-everything oracle (>2x headroom on the relative gate, immune to
  runner hardware), loose absolute backstop, batched plan_wake >3x, and
  the end-to-end batched step >2x over the per-task execute loop.
- **sim** — fixed-seed sim is byte-deterministic, green mode beats
  performance mode under load, accurate-forecast deferral beats run-now,
  forecast error degrades savings monotonically, static-scenario parity.
- **tenancy** — closed-loop multi-tenant sim is byte-deterministic (across
  a repeat run AND across the batched/scalar execute paths); no capped
  tenant's single-period spend exceeds its allowance by more than one
  task's carbon; the admission-enabled end-to-end step stays under a
  loose absolute per-task bound and within a small factor of the
  tenancy-free step (the 30 µs/task paper-budget row is the full
  ``benchmarks/tenancy_saturation.py`` run); writes BENCH_tenancy.json.
- **partition** — reduced joint partition+placement sweep (DESIGN.md §8):
  the (B, P, N) numpy column path bit-exact with the cut-major scalar
  oracle, the end-to-end ``engine.step`` with a PartitionPolicy (select +
  effective-latency execute + bill) under a loose absolute per-task
  bound with both execute paths bit-identical, risk-bounded deferral
  planning satisfying the never-defer invariant at tight AND wide
  conformal bands, and split-conformal held-out coverage >= 0.87 against
  the 90% target; writes BENCH_partition.json.
- **obs** — observability (DESIGN.md §9, §12): a fixed-seed sim renders
  a byte-identical ``metrics.to_text`` whether obs is absent, disabled,
  or fully enabled, across both execute paths AND both event queues;
  journeys/rollups/alerts render byte-identically on a fixed-seed chaos
  scenario across a repeat run and the calendar/heap queues, with at
  least one alert firing and the journey phase-sum identity holding;
  with ALL six pillars ON, the end-to-end ``engine.step`` stays <= 1.3x
  the disabled path on the N=10^4, B=1024 acceptance row (median of
  interleaved adjacent-pair ratios; small rows where fixed costs
  dominate get the documented small-shape backstop) and never changes a
  decision; a 10^5-client closed-loop run exports rollups with memory
  O(windows); writes BENCH_obs.json.
- **sim_scale** — internet-scale sim (DESIGN.md §11): the array-based
  event calendar is byte-identical with the scalar heap oracle on a
  real-engine scenario across event_queue x batch_execute, on every
  measured replay and closed-loop row, and on a 24 h multi-region CSV
  trace replay; open-loop replay rows at >=10^5 events must show >=10x
  per-event speedup over the heap (closed-loop rows, fragmented by the
  oracle's own window-flush semantics, get a loose floor) plus a loose
  absolute per-event backstop; writes BENCH_sim_scale.json.
- **trend** — compare this checkout's per-task/per-event costs against a
  previous main-branch run (CI restores a ``bench-baseline/`` directory
  holding every ``BENCH_*.json`` via actions/cache) and fail on a >2x
  relative regression on any matching row; rows are discovered
  recursively from the JSON, so new benchmark files are covered without
  per-file code. ``--baseline`` accepts the directory or a single file.

Each gate returns the measured payload so callers can log it; failures
raise ``AssertionError`` with the offending row attached.
"""
from __future__ import annotations

import json
import os
from typing import Callable, Dict, Optional

# Trend gate: fail when a matching row got more than this factor slower.
TREND_MAX_SLOWDOWN_X = 2.0


def gate_overhead() -> Dict:
    from benchmarks import overhead

    out = overhead.run()
    assert out["per_task_ms"] < 0.5, out
    assert out["route_select_ms"] < 0.1, out
    assert out["engine_batch256_per_task_ms"] < 0.03, out
    assert out["engine_batch256_per_task_ms"] < out["per_task_ms"], \
        "batched engine selection slower than the scalar loop"
    # end-to-end: the WHOLE step (select + execute + bill) inside the
    # paper's 0.03 ms/task budget, and no slower than the per-task
    # execute loop it replaced
    assert out["engine_step_e2e_per_task_ms"] < 0.03, out
    assert (out["engine_step_e2e_per_task_ms"]
            <= out["engine_step_scalar_exec_per_task_ms"]), \
        "batched execution slower than the per-task execute loop"
    return out


def gate_fleet(out_path: str = "BENCH_fleet_scale.json") -> Dict:
    from benchmarks import fleet_scale

    out = fleet_scale.run(smoke=True, out_path=out_path)
    for r in out["select"]:
        assert r["speedup_x"] > 3.0, r
        assert r["cached_per_task_ms"] < 0.5, r
    for r in out["plan_wake"]:
        assert r["speedup_x"] > 3.0, r
    for r in out["step"]:
        # end-to-end batched step vs the per-task execute loop: relative
        # gate at smoke scale (measured ~3-4x at N=2048, B=256; the >=5x
        # acceptance number is the full-sweep N=10^4, B=1024 row)
        assert r["speedup_x"] > 2.0, r
        assert r["batched_per_task_ms"] < 0.5, r
    return out


def gate_sim() -> Dict:
    from benchmarks import sim_serving

    a = sim_serving.run()
    b = sim_serving.run()
    for x, y in zip(a["deferral"], b["deferral"]):
        assert x["carbon_g_total"] == y["carbon_g_total"], (x, y)
    ra, rb = a["rate_mode"], b["rate_mode"]
    assert [r["wait_histogram"] for r in ra] == \
        [r["wait_histogram"] for r in rb], "wait histogram not deterministic"
    green = [r for r in ra if r["mode"] == "green"]
    perf = [r for r in ra if r["mode"] == "performance"]
    for g, p in zip(green, perf):
        assert g["carbon_g_per_task"] < p["carbon_g_per_task"], (g, p)
    run_now = a["deferral"][0]["carbon_g_total"]
    regrets = [r["regret_g"] for r in a["deferral"][1:]]
    assert a["deferral"][1]["carbon_g_total"] < run_now, \
        "deferral lost to run-now"
    assert all(x <= y + 1e-12 for x, y in zip(regrets, regrets[1:])), regrets
    assert a["parity"]["carbon_match"] and \
        a["parity"]["distribution_match"], a["parity"]
    return a


def gate_tenancy(out_path: str = "BENCH_tenancy.json") -> Dict:
    from benchmarks import tenancy_saturation

    out = tenancy_saturation.run(smoke=True, out_path=out_path)
    d = out["determinism"]
    assert d["repeat_match"], "closed-loop sim not repeat-deterministic"
    assert d["exec_path_match"], \
        "closed-loop sim diverged across batched/scalar execute paths"
    for r in out["saturation"]:
        # admission invariant: <= one task's carbon of overshoot in any
        # accounting period, for every capped tenant
        assert r["max_overshoot_tasks"] <= 1.0 + 1e-9, r
        assert r["completed"] > 0, r
    for r in out["overhead"]:
        # loose absolute bound (CI runners vary) + relative bound vs the
        # tenancy-free engine step on the same fleet and request mix
        assert r["tenancy_per_task_ms"] < 0.5, r
        assert r["overhead_x"] < 20.0, r
    return out


def gate_partition(out_path: str = "BENCH_partition.json") -> Dict:
    from benchmarks import partition_scale

    out = partition_scale.run(smoke=True, out_path=out_path)
    for r in out["select"]:
        assert r["parity_ok"], r
        assert r["joint_per_task_ms"] < 0.5, r
    for r in out["step"]:
        assert r["exec_path_parity"], r
        # loose absolute backstop at smoke scale; the 30 us/task paper-
        # budget row is the full-sweep N=10^4, B=1024, P=32 run
        assert r["per_task_ms"] < 0.5, r
    for r in out["risk"]:
        assert r["invariant_ok"], r
    tight = [r for r in out["risk"] if r["sigma"] < 1.0]
    assert tight and all(r["deferred"] > 0 for r in tight), \
        "tight conformal band certified no deferrals (vacuous invariant)"
    assert out["conformal"]["heldout_coverage"] >= 0.87, out["conformal"]
    return out


def gate_obs(out_path: str = "BENCH_obs.json") -> Dict:
    from benchmarks import obs_overhead

    out = obs_overhead.run(smoke=True, out_path=out_path)
    for key, ok in out["byte_identity"].items():
        assert ok, f"sim metrics text diverged with obs wired: {key}"
    for key, ok in out["journey_determinism"].items():
        # journeys/rollups/alerts byte-determinism on the chaos scenario
        # (repeat run + calendar/heap queues), metrics byte identity with
        # obs on BOTH engine and driver, >=1 alert actually firing, and
        # the phase-sum identity (journey phases add up to e2e latency)
        assert ok, f"journey/rollup/alert determinism broken: {key}"
    bound = out["overhead_bound_x"]
    small_bound = out["small_shape_bound_x"]
    for r in out["rows"]:
        # the disabled path must stay a normal engine step (same loose
        # absolute backstop as the other gates)
        assert r["disabled_per_task_ms"] < 0.5, r
        if (r["n_nodes"], r["batch"]) == (10_000, 1024):
            # the acceptance bound is defined at this row, where per-task
            # work dominates the per-step fixed costs
            assert r["overhead_x"] <= bound, r
        else:
            # small rows amortize the fixed per-step obs cost over few
            # tasks — bounded by the documented small-shape backstop
            # (see obs_overhead.SMALL_SHAPE_RATIONALE)
            assert r["overhead_x"] <= small_bound, r
    assert any((r["n_nodes"], r["batch"]) == (10_000, 1024)
               for r in out["rows"]), "acceptance row missing from sweep"
    scale = out["rollup_scale"]
    # 10^5-client closed-loop run: rollups must export with memory
    # O(windows) — bounded by window capacity, independent of task count
    assert scale["n_clients"] >= 100_000, scale
    assert scale["tasks"] >= 100_000, scale
    assert scale["memory_ok"], scale
    assert scale["rollup_nbytes"] < (1 << 20), scale
    return out


def gate_resilience(out_path: str = "BENCH_resilience.json") -> Dict:
    from benchmarks import resilience_churn

    out = resilience_churn.run(smoke=True, out_path=out_path)
    for key, ok in out["byte_identity"].items():
        # zero-fault schedules must render byte-identically with
        # resilience wired, and a fixed fault seed must repeat exactly
        assert ok, f"resilience determinism contract broken: {key}"
    oh = out["overhead"]
    assert oh["overhead_x"] <= out["overhead_bound_x"], oh
    base = out["cells"][0]
    assert (base["crash_rate_per_hour"],
            base["outage_rate_per_hour"]) == (1.0, 0.0), \
        "baseline-churn cell missing from sweep"
    floor = out["availability_floor"]
    assert base["request_availability"] >= floor, base
    for c in out["cells"]:
        # every cell must keep serving: completions despite churn, and
        # every submitted request resolved (completed or dead-lettered —
        # nothing silently lost)
        assert c["completed"] > 0, c
    return out


def gate_sim_scale(out_path: str = "BENCH_sim_scale.json") -> Dict:
    from benchmarks import sim_scale

    out = sim_scale.run(smoke=True, out_path=out_path)
    for key, ok in out["byte_identity"].items():
        assert ok, f"heap-oracle contract broken: {key}"
    tr = out["trace_replay"]
    assert tr["repeat_match"] and tr["queue_match"] \
        and tr["exec_path_match"], tr
    for r in out["replay"] + out["closed_loop"]:
        assert r["byte_identity"], r
        # loose absolute backstop (CI runners vary)
        assert r["calendar_per_event_us"] < 50.0, r
    big = [r for r in out["replay"] if r["events"] >= 100_000]
    assert big, "replay sweep lost its >=10^5-event acceptance row"
    for r in big:
        # the acceptance number: pure array drains at scale
        assert r["speedup_x"] >= 10.0, r
    for r in out["closed_loop"]:
        # window-flush re-arming fragments runs identically in both
        # queues (oracle semantics), so byte identity is the contract
        # here and speed only a loose floor
        assert r["speedup_x"] > 1.5, r
    return out


# Suffixes of the cost metrics the trend gate tracks across runs.
_TREND_SUFFIXES = ("per_task_ms", "per_event_us")


def _trend_rows(bench, prefix: tuple = ()) -> Dict[tuple, float]:
    """(path, row-identity, metric) -> value for every per-task /
    per-event cost in a bench JSON, discovered recursively so new
    benchmark files are tracked without per-file code. A row's identity
    is its scalar non-metric fields (n_nodes, batch, n_clients, ...), so
    reordering a sweep doesn't fake a regression and a reshaped sweep
    simply stops matching."""
    rows: Dict[tuple, float] = {}
    if isinstance(bench, dict):
        metrics = {k: v for k, v in bench.items()
                   if isinstance(v, (int, float)) and not isinstance(v, bool)
                   and k.endswith(_TREND_SUFFIXES)}
        if metrics:
            ident = tuple(sorted(
                (k, v) for k, v in bench.items()
                if isinstance(v, (str, int)) and not isinstance(v, bool)
                and not k.endswith(_TREND_SUFFIXES)))
            for k, v in metrics.items():
                rows[(prefix, ident, k)] = float(v)
        for k, v in bench.items():
            rows.update(_trend_rows(v, prefix + (k,)))
    elif isinstance(bench, list):
        for item in bench:
            rows.update(_trend_rows(item, prefix))
    return rows


def _trend_compare(base: Dict[tuple, float], cur: Dict[tuple, float],
                   label: str):
    compared, failures = 0, []
    for key, base_v in sorted(base.items()):
        cur_v = cur.get(key)
        if cur_v is None or base_v <= 0:
            continue
        compared += 1
        ratio = cur_v / base_v
        path, ident, metric = key
        name = "/".join(path + (metric,))
        print(f"trend {label} {name} {dict(ident)}: "
              f"{base_v:.4g} -> {cur_v:.4g}  ({ratio:.2f}x)")
        if ratio > TREND_MAX_SLOWDOWN_X:
            failures.append((label, key, base_v, cur_v, ratio))
    return compared, failures


def gate_trend(baseline: Optional[str] = None,
               current: str = "BENCH_fleet_scale.json") -> Dict:
    """Relative regression gate against a previous run's bench output.

    ``baseline`` is normally the cached ``bench-baseline/`` directory —
    every ``BENCH_*.json`` it holds is compared against the same-named
    file in the working directory (written by the smoke gates earlier in
    the CI job). A single baseline file is still accepted and compared
    against ``current``. Passes (with a notice) when there is no
    baseline yet — the first run on a fresh cache has nothing to compare
    against — and when the baseline has no matching rows (sweep shape
    changed)."""
    if baseline is None or not os.path.exists(baseline):
        print(f"trend: no baseline at {baseline!r}; nothing to compare")
        return {"compared": 0}
    if os.path.isdir(baseline):
        pairs = []
        for name in sorted(os.listdir(baseline)):
            if not (name.startswith("BENCH_") and name.endswith(".json")):
                continue
            if not os.path.exists(name):
                print(f"trend: no current {name}; skipping")
                continue
            pairs.append((name, os.path.join(baseline, name), name))
    else:
        if not os.path.exists(current):
            # gate_fleet writes it; standalone trend runs may need to
            gate_fleet(out_path=current)
        pairs = [(os.path.basename(baseline), baseline, current)]
    compared, failures = 0, []
    for label, base_path, cur_path in pairs:
        with open(base_path) as f:
            base = _trend_rows(json.load(f))
        with open(cur_path) as f:
            cur = _trend_rows(json.load(f))
        c, fails = _trend_compare(base, cur, label)
        compared += c
        failures += fails
    assert not failures, (
        f">{TREND_MAX_SLOWDOWN_X:.1f}x per-task regression vs baseline: "
        f"{failures}")
    if not compared:
        print("trend: baseline had no matching rows; nothing to compare")
    return {"compared": compared}


GATES: Dict[str, Callable] = {
    "overhead": gate_overhead,
    "fleet": gate_fleet,
    "sim": gate_sim,
    "tenancy": gate_tenancy,
    "partition": gate_partition,
    "obs": gate_obs,
    "resilience": gate_resilience,
    "sim_scale": gate_sim_scale,
    "trend": gate_trend,
}


def main(gate: str = "all", baseline: Optional[str] = None) -> Dict:
    """Run one gate (or all) with the exact assertions CI uses."""
    names = list(GATES) if gate == "all" else [gate]
    results = {}
    for name in names:
        if name not in GATES:
            raise SystemExit(
                f"unknown gate {name!r}; choose from {sorted(GATES)} or 'all'")
        print(f"== gate: {name} ==")
        if name == "trend":
            results[name] = gate_trend(baseline=baseline)
        else:
            results[name] = GATES[name]()
        print(f"== gate {name}: PASS ==")
    return results


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("gate", nargs="?", default="all",
                   help=f"one of {sorted(GATES)} or 'all' (default)")
    p.add_argument("--baseline", default=None,
                   help="baseline for the trend gate: a bench-baseline/ "
                        "directory of BENCH_*.json files, or a single file")
    args = p.parse_args()
    main(gate=args.gate, baseline=args.baseline)
