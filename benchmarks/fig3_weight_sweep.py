"""Paper Fig. 3: carbon-weight sweep; transition to green at w_C >= 0.50."""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run(model: str = "mobilenetv2", points=None):
    points = points if points is not None else np.arange(0.0, 0.95, 0.05)
    mono = common.run_monolithic(model)
    rows = []
    for w_c in points:
        r = common.run_sweep_point(model, float(w_c))
        dist = r["distribution"]
        rows.append({
            "w_c": round(float(w_c), 2),
            "green_share_pct": dist["node-green"],
            "carbon_g_per_inf": r["totals"]["carbon_g_per_inf"],
            "latency_ms": r["totals"]["avg_latency_ms"],
            "reduction_pct": common.reduction_vs_mono(model, r, mono),
        })
    transition = next((r["w_c"] for r in rows if r["green_share_pct"] > 50.0), None)
    return {"rows": rows, "transition_w_c": transition}


def main():
    out = run()
    print(f"{'w_C':>5s} {'green%':>7s} {'gCO2/inf':>9s} {'red%':>6s}")
    for r in out["rows"]:
        print(f"{r['w_c']:5.2f} {r['green_share_pct']:7.0f} "
              f"{r['carbon_g_per_inf']:9.5f} {r['reduction_pct']:6.1f}")
    print(f"transition at w_C = {out['transition_w_c']} (paper: >= 0.50)")
    return out


if __name__ == "__main__":
    main()
