"""Multi-tenant saturation, budget fairness and admission overhead
(repro.tenancy, DESIGN.md §7).

Three sections, written to ``BENCH_tenancy.json``:

- **saturation** — closed-loop client populations (think-time, retry on
  SLO miss, abandon after k tries) over the paper's 3-node cluster,
  swept across load (clients per tenant) x allowance regime. Because the
  load is closed-loop, offered throughput *reacts* to queueing delay and
  admission decisions — the saturation/abandon behaviour the open-loop
  sweeps in sim_serving.py assume away. Also reports budget-enforcement
  fairness: Jain's index over each capped tenant's spend/allowance ratio
  (1.0 = every tenant got the same fraction of its own allowance), and
  the worst per-period allowance overshoot in units of one task's carbon
  (the admission invariant: must stay <= 1).
- **determinism** — the closed-loop sim's `metrics.to_text` is
  byte-identical across a repeat run and across the batched vs scalar
  execute paths (the DESIGN.md §2.2 contract extended to tenancy).
- **overhead** — end-to-end `engine.step` (admission plan + escalated
  selection + execute + bill + tenant charging) at fleet scale vs the
  same engine without tenancy, against the paper's 30 µs/task budget.

CI runs ``run(smoke=True)`` (reduced sweep); the gate assertions live in
``benchmarks/ci_gates.py`` (locally: ``python -m benchmarks.ci_gates
tenancy``).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.api import CarbonEdgeEngine
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.sim import AsyncEngineDriver, ClientPopulation, ClosedLoopClientPool
from repro.tenancy import (SLOClass, TenantPolicy, TenantRegistry, TenantSpec,
                           TenantTask)

PAPER_PER_TASK_MS = 0.03
BASE_LATENCY_MS = 250.0
SEED = 11


# -- closed-loop scenario -----------------------------------------------------


def _specs(allowance_scale: float, period_hours: float) -> List[TenantSpec]:
    """Three-tenant mix: an interactive gold tenant, a capped standard
    tenant and a batch-class tenant that prefers green placements."""
    return [
        TenantSpec("gold", slo=SLOClass(latency_s=1.0), priority=2),
        TenantSpec("std", allowance_g=0.05 * allowance_scale,
                   period_hours=period_hours,
                   slo=SLOClass(latency_s=2.0), priority=1),
        TenantSpec("batch", allowance_g=0.05 * allowance_scale,
                   period_hours=period_hours, mode="green",
                   slo=SLOClass(latency_s=10.0, miss_tolerance=0.5)),
    ]


def run_closed_loop(clients_per_tenant: int, allowance_scale: float, *,
                    horizon_hours: float = 0.05, period_hours: float = 0.02,
                    batch_execute: bool = True, seed: int = SEED):
    cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    cluster.profile(BASE_LATENCY_MS)
    registry = TenantRegistry(_specs(allowance_scale, period_hours))
    engine = CarbonEdgeEngine(cluster, mode="balanced",
                              policy=TenantPolicy(registry=registry),
                              batch_execute=batch_execute)
    pool = ClosedLoopClientPool([
        ClientPopulation("gold", clients_per_tenant,
                         mean_think_hours=0.002, slo_latency_s=1.0,
                         priority=2),
        ClientPopulation("std", clients_per_tenant,
                         mean_think_hours=0.002, slo_latency_s=2.0,
                         priority=1),
        ClientPopulation("batch", clients_per_tenant,
                         mean_think_hours=0.004, slo_latency_s=10.0),
    ], seed=seed)

    def factory(uid: int, hour: float, tenant: str):
        return TenantTask(cpu=0.05, mem_mb=16.0,
                          base_latency_ms=BASE_LATENCY_MS, tenant=tenant)

    driver = AsyncEngineDriver(engine, None, factory, start_hour=0.0,
                               horizon_hours=horizon_hours, max_batch=8,
                               slo_latency_s=10.0, clients=pool)
    metrics = driver.run()
    return metrics, registry


def _jain(xs: np.ndarray) -> float:
    xs = np.asarray(xs, dtype=float)
    if not xs.size or not np.any(xs > 0):
        return 1.0
    return float(xs.sum() ** 2 / (xs.size * (xs ** 2).sum()))


def saturation_sweep(loads=(2, 6, 16), scales=(4.0, 1.0),
                     horizon_hours: float = 0.05) -> List[Dict]:
    rows = []
    for scale in scales:
        for n in loads:
            m, reg = run_closed_loop(n, scale,
                                     horizon_hours=horizon_hours)
            ts = m.tenant_summary()
            completed = sum(t["completed"] for t in ts.values())
            capped = np.isfinite(reg.allowance_g)
            # fairness of budget enforcement: each capped tenant's total
            # spend normalised by the allowance-periods it lived through —
            # Jain index 1.0 == every tenant realised the same fraction of
            # its own budget
            periods = np.maximum(reg.period_idx[capped] + 1, 1)
            frac = (reg.total_carbon_g[capped]
                    / (reg.allowance_g[capped] * periods))
            # admission invariant: worst single-period overshoot, in units
            # of one task's carbon (greenest placement on this cluster)
            greenest_i = min(n_.carbon_intensity for n_ in PAPER_NODES)
            _, e = EdgeCluster(nodes=PAPER_NODES).latency_energy(
                np.array([BASE_LATENCY_MS]))
            task_g = float(e[0] * greenest_i)
            overshoot = float(np.max(
                reg.peak_spent_g[capped] - reg.allowance_g[capped])
                / task_g)
            rows.append({
                "clients_per_tenant": n, "allowance_scale": scale,
                "completed": completed,
                "throughput_per_hour": completed / horizon_hours,
                "abandoned": sum(t["abandoned"] for t in ts.values()),
                "rejected": sum(t["rejected"] for t in ts.values()),
                "deferred": sum(t["deferred"] for t in ts.values()),
                "slo_attainment": {k: t["slo_attainment"]
                                   for k, t in ts.items()},
                "carbon_g": {k: t["carbon_g"] for k, t in ts.items()},
                "budget_fairness_jain": _jain(frac),
                "max_overshoot_tasks": overshoot,
            })
    return rows


def determinism_check() -> Dict:
    a, _ = run_closed_loop(6, 1.0)
    b, _ = run_closed_loop(6, 1.0)
    c, _ = run_closed_loop(6, 1.0, batch_execute=False)
    return {"repeat_match": a.to_text() == b.to_text(),
            "exec_path_match": a.to_text() == c.to_text()}


# -- admission overhead at fleet scale ---------------------------------------


def _time(fn, reps: int) -> float:
    fn()                                   # warm (cache build, jit)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _mixed_tasks(b: int, tenants: List[str], seed: int = 0) -> List[TenantTask]:
    rng = np.random.default_rng(seed)
    profiles = [(float(rng.uniform(0.01, 0.5)),
                 float(rng.uniform(8.0, 128.0))) for _ in range(8)]
    return [TenantTask(cpu=c, mem_mb=m, base_latency_ms=BASE_LATENCY_MS,
                       tenant=tenants[i % len(tenants)])
            for i, (c, m) in ((i, profiles[i % len(profiles)])
                              for i in range(b))]


def bench_overhead(n_nodes: int, batch: int, reps: int = 5) -> Dict:
    """End-to-end engine.step per-task time with admission control on vs
    off, same fleet and request mix. The tenancy engine carries four
    registered tenants (one unlimited, three capped) so the plan phase
    exercises real budget math every step."""
    from benchmarks.fleet_scale import make_fleet

    tenants = ["free", "t1", "t2", "t3"]
    tasks = _mixed_tasks(batch, tenants)

    def make_engine(with_tenancy: bool) -> CarbonEdgeEngine:
        fleet = make_fleet(n_nodes)
        if not with_tenancy:
            return CarbonEdgeEngine(fleet, mode="green")
        # mode="green" floors every tenant at the plain engine's weights,
        # so both engines make identical placements and the delta is the
        # admission machinery alone, not a mode change
        reg = TenantRegistry(
            [TenantSpec("free", mode="green")]
            + [TenantSpec(t, allowance_g=1e6, period_hours=24.0,
                          mode="green") for t in ("t1", "t2", "t3")])
        return CarbonEdgeEngine(fleet, mode="green",
                                policy=TenantPolicy(registry=reg))

    def step(engine: CarbonEdgeEngine):
        def fn():
            engine.submit_many(tasks)
            engine.step(now_hour=0.0)
        return fn

    plain = _time(step(make_engine(False)), reps)
    tenanted = _time(step(make_engine(True)), reps)
    return {
        "n_nodes": n_nodes, "batch": batch,
        "plain_per_task_ms": plain * 1e3 / batch,
        "tenancy_per_task_ms": tenanted * 1e3 / batch,
        "admission_overhead_us_per_task": (tenanted - plain) * 1e6 / batch,
        "overhead_x": tenanted / plain,
        "paper_per_task_ms": PAPER_PER_TASK_MS,
        "within_paper_budget": tenanted * 1e3 / batch < PAPER_PER_TASK_MS,
    }


def run(smoke: bool = False,
        out_path: Optional[str] = "BENCH_tenancy.json") -> Dict:
    if smoke:
        sat = saturation_sweep(loads=(2, 6), scales=(1.0,),
                               horizon_hours=0.03)
        overhead = [bench_overhead(2_048, 256, reps=3)]
    else:
        sat = saturation_sweep()
        overhead = [bench_overhead(n, b, reps=5)
                    for n, b in ((2_048, 256), (10_000, 1_024))]
    out = {"saturation": sat, "determinism": determinism_check(),
           "overhead": overhead}
    if out_path:
        with open(out_path, "w") as f:
            json.dump(out, f, indent=1)
    return out


def main() -> Dict:
    out = run()
    print(f"{'clients':>8s} {'scale':>6s} {'done':>6s} {'abandon':>7s} "
          f"{'reject':>6s} {'defer':>6s} {'fair':>6s} {'over':>6s}")
    for r in out["saturation"]:
        print(f"{r['clients_per_tenant']:8d} {r['allowance_scale']:6.1f} "
              f"{r['completed']:6d} {r['abandoned']:7d} {r['rejected']:6d} "
              f"{r['deferred']:6d} {r['budget_fairness_jain']:6.3f} "
              f"{r['max_overshoot_tasks']:6.2f}")
    d = out["determinism"]
    print(f"\ndeterminism: repeat={d['repeat_match']} "
          f"exec_path={d['exec_path_match']}")
    print(f"\n{'nodes':>8s} {'batch':>6s} {'plain us':>9s} "
          f"{'tenancy us':>10s} {'admit us':>9s} {'budget':>7s}")
    for r in out["overhead"]:
        print(f"{r['n_nodes']:8d} {r['batch']:6d} "
              f"{r['plain_per_task_ms']*1e3:9.2f} "
              f"{r['tenancy_per_task_ms']*1e3:10.2f} "
              f"{r['admission_overhead_us_per_task']:9.2f} "
              f"{'PASS' if r['within_paper_budget'] else 'FAIL':>7s}")
    return out


if __name__ == "__main__":
    main()
