"""Joint partition+placement scheduling at fleet scale (DESIGN.md §8).

Sweeps fleet size N x batch size B x candidate cuts P and times:

- **joint select** — ``PartitionPolicy.decide_batch`` over the (B, P, N)
  decision plane (numpy column path, selection memo off so the rows
  measure the scoring pass), with bit-exact parity against the cut-major
  scalar oracle asserted on a sampled sub-batch;
- **step** — the END-TO-END ``CarbonEdgeEngine.step`` with a
  ``PartitionPolicy`` (select + effective-latency execute + bill): the
  paper's 0.03 ms/task budget for the whole joint decision, measured at
  the production defaults (feature cache + selection memo + batched
  execute). The acceptance row is N=10^4, B=1024, P=32;
- **risk planning** — ``plan_wake_risk_batch`` (two interval grid reads)
  vs the point-forecast ``plan_wake_batch``, plus the never-defer
  invariant re-checked against raw provider reads;
- **conformal** — split-conformal intensity calibration on noisy
  synthetic traces: held-out coverage at the 90% target (gate asserts
  >= 0.87).

Writes ``BENCH_partition.json``. The CI smoke runs ``run(smoke=True)``;
gate assertions live in ``benchmarks/ci_gates.py``
(``python -m benchmarks.ci_gates partition``).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np

from benchmarks.fleet_scale import PAPER_PER_TASK_MS, _time, make_fleet, make_tasks
from repro.core.api import (CarbonEdgeEngine, ForecastProvider, StaticProvider,
                            TraceProvider, intensity_interval_batch)
from repro.core.scheduler import MODES
from repro.core.temporal import (DeferrableTask, plan_wake_batch,
                                 plan_wake_risk_batch, synthetic_trace)
from repro.partition import (ConformalProvider, PartitionPolicy, SplitConformal,
                             calibrate_intensity, profile_costs,
                             select_joint_scalar)

FULL_NS = (1_000, 10_000)
FULL_BS = (256, 1024)
FULL_PS = (8, 32)
SMOKE_NS = (512, 2_048)
SMOKE_BS = (64,)
SMOKE_PS = (8,)


def make_profile(p: int, seed: int = 0):
    """Synthetic per-layer costs/boundaries yielding exactly ``p`` cuts."""
    rng = np.random.default_rng(seed)
    costs = rng.uniform(1.0, 20.0, p)
    bb = np.append(rng.uniform(1e4, 1e6, p - 1), 0.0)
    return profile_costs(costs, boundary_bytes=bb, name=f"synth{p}")


def bench_joint_select(cluster, tasks, prof, *, reps: int) -> Dict:
    w = MODES["green"]
    provider = StaticProvider.from_cluster(cluster)
    pol = PartitionPolicy(prof, backend="numpy", use_select_memo=False)
    names = list(cluster.nodes)

    def step():
        # dirty a handful of nodes between steps, like a live engine would
        for nm in names[:8]:
            cluster.nodes[nm].running += 1
            cluster.nodes[nm].running -= 1
        return pol.decide_batch(cluster, tasks, w, provider)

    joint_s = _time(step, reps)
    # bit-exact parity with the cut-major scalar oracle on a sample
    sample = tasks[:: max(1, len(tasks) // 8)]
    got = pol.decide_batch(cluster, sample, w, provider)
    parity_ok = True
    for t, d in zip(sample, got):
        ref = select_joint_scalar(cluster, t, prof, w, provider=provider)
        ok = ((d is None and ref is None)
              or (d is not None and ref is not None
                  and (d.node, d.cut, d.score)
                  == (ref.node, ref.cut, ref.score)))
        parity_ok = parity_ok and ok
    b = len(tasks)
    return {
        "n_nodes": len(names), "batch": b, "cuts": prof.num_cuts,
        "joint_step_ms": joint_s * 1e3,
        "joint_per_task_ms": joint_s * 1e3 / b,
        "joint_tasks_per_sec": b / joint_s,
        "parity_ok": parity_ok,
        "paper_per_task_ms": PAPER_PER_TASK_MS,
    }


def bench_step(n: int, b: int, p: int, *, reps: int, seed: int = 0) -> Dict:
    """End-to-end ``engine.step`` with a PartitionPolicy at production
    defaults, plus bit-exact parity of the two execute paths under the
    effective-latency hook."""
    prof = make_profile(p, seed=seed)

    def run_path(batch_execute: bool, reps: int) -> float:
        eng = CarbonEdgeEngine(make_fleet(n, seed=seed),
                               policy=PartitionPolicy(prof, backend="numpy"),
                               batch_execute=batch_execute)
        tasks = make_tasks(b, seed=seed)
        eng.submit_many(tasks)
        eng.step()                         # warm (cache + memo fill)
        best = float("inf")
        for _ in range(reps):
            eng.submit_many(tasks)
            t0 = time.perf_counter()
            eng.step()
            best = min(best, time.perf_counter() - t0)
        return best

    batched_s = run_path(True, reps)
    ea = CarbonEdgeEngine(make_fleet(n, seed=seed),
                          policy=PartitionPolicy(prof, backend="numpy"),
                          batch_execute=False)
    eb = CarbonEdgeEngine(make_fleet(n, seed=seed),
                          policy=PartitionPolicy(prof, backend="numpy"),
                          batch_execute=True)
    tasks = make_tasks(b, seed=seed)
    ra = ea.submit_many(tasks).step()
    rb = eb.submit_many(tasks).step()
    exec_parity = (ra == rb and ea.cluster.log == eb.cluster.log
                   and ea.monitor.report() == eb.monitor.report())
    return {
        "n_nodes": n, "batch": b, "cuts": p,
        "step_ms": batched_s * 1e3,
        "per_task_ms": batched_s * 1e3 / b,
        "tasks_per_sec": b / batched_s,
        "exec_path_parity": exec_parity,
        "paper_per_task_ms": PAPER_PER_TASK_MS,
        "vs_paper_x": (batched_s * 1e3 / b) / PAPER_PER_TASK_MS,
    }


def bench_risk_planning(n: int, *, reps: int, seed: int = 0,
                        sigma: float = 0.5) -> Dict:
    """``sigma`` is the forecast residual spread the conformal band is
    calibrated from: tight bands (default) certify most of the point
    planner's deferrals, wide bands (see the ``sigma=20`` row) make the
    planner abstain — both must satisfy the never-defer invariant."""
    cluster = make_fleet(n, seed=seed)
    rng = np.random.default_rng(seed)
    traces = {nm: synthetic_trace(nm, st.spec.carbon_intensity, seed=i % 16)
              for i, (nm, st) in enumerate(cluster.nodes.items())}
    base = TraceProvider(traces)
    prov = ConformalProvider(base, SplitConformal(rng.normal(0, sigma, 200)))
    tasks = [DeferrableTask(cpu=0.05, mem_mb=16.0,
                            deadline_hours=float(rng.uniform(2.0, 12.0)),
                            duration_hours=0.5) for _ in range(64)]
    # morning submit: the midday solar dip is inside the longer deadlines,
    # so risk planning has genuine deferrals to certify
    now = 8.0
    point_s = _time(lambda: plan_wake_batch(prov, cluster, tasks, now), reps)
    risk_s = _time(lambda: plan_wake_risk_batch(prov, cluster, tasks, now),
                   reps)
    # never-defer invariant, re-derived from raw provider interval reads
    wakes = plan_wake_risk_batch(prov, cluster, tasks, now)
    names = list(cluster.nodes)
    invariant_ok = True
    for t, wk in zip(tasks, wakes):
        if wk == now:
            continue
        lo0, _ = intensity_interval_batch(prov, names, now)
        _, hi_w = intensity_interval_batch(prov, names, float(wk))
        invariant_ok = invariant_ok and \
            float(np.min(hi_w)) < float(np.min(lo0))
    return {
        "n_nodes": n, "tasks": len(tasks), "sigma": sigma,
        "point_ms": point_s * 1e3,
        "risk_ms": risk_s * 1e3,
        "risk_overhead_x": risk_s / point_s,
        "deferred": int(np.sum(wakes > now)),
        "invariant_ok": invariant_ok,
    }


def bench_conformal(seed: int = 0) -> Dict:
    """Held-out interval coverage of split-conformal intensity calibration
    on noisy duck-curve traces (nominal 90%)."""
    regions = [("coal-heavy", 620.0), ("cn-average", 530.0),
               ("hydro-rich", 380.0), ("solar-mix", 450.0)]
    actual = TraceProvider({r: synthetic_trace(r, b, noise=0.08,
                                               seed=seed + i)
                            for i, (r, b) in enumerate(regions)})
    forecast = ForecastProvider(
        TraceProvider({r: synthetic_trace(r, b) for r, b in regions}),
        smoothing_hours=2.0)
    names = [r for r, _ in regions]
    cal_hours = np.arange(0.0, 24.0, 0.25)
    sc = calibrate_intensity(forecast, actual, names, cal_hours)
    prov = ConformalProvider(forecast, sc)
    test_hours = np.arange(0.125, 24.0, 0.25)     # held-out offsets
    lo, hi = prov.intensity_interval_batch(names, test_hours, coverage=0.9)
    truth = actual.intensity_batch(names, test_hours)
    coverage = float(np.mean((truth >= lo) & (truth <= hi)))
    return {
        "nominal": 0.9,
        "heldout_coverage": coverage,
        "quantile_g_per_kwh": sc.quantile(0.9),
        "calibration_points": sc.n,
    }


def run(smoke: bool = False, out_path: str = "BENCH_partition.json") -> Dict:
    ns = SMOKE_NS if smoke else FULL_NS
    bs = SMOKE_BS if smoke else FULL_BS
    ps = SMOKE_PS if smoke else FULL_PS
    select_rows, step_rows, risk_rows = [], [], []
    for n in ns:
        cluster = make_fleet(n)
        for p in ps:
            prof = make_profile(p)
            for b in bs:
                reps = 20 if n * p <= 100_000 else 5
                row = bench_joint_select(cluster, make_tasks(b), prof,
                                         reps=reps)
                select_rows.append(row)
                print(f"joint  N={n:>6} B={b:>5} P={p:>3}: "
                      f"{row['joint_step_ms']:8.3f} ms "
                      f"({row['joint_per_task_ms']*1e3:8.2f} us/task, "
                      f"parity={'ok' if row['parity_ok'] else 'FAIL'})")
    for n in ns:
        for p in ps:
            b = max(bs)
            row = bench_step(n, b, p, reps=10 if n <= 10_000 else 3)
            step_rows.append(row)
            print(f"step   N={n:>6} B={b:>5} P={p:>3}: "
                  f"{row['step_ms']:8.3f} ms "
                  f"({row['per_task_ms']*1e3:8.2f} us/task, paper budget "
                  f"{PAPER_PER_TASK_MS*1e3:.0f} us, "
                  f"exec parity={'ok' if row['exec_path_parity'] else 'FAIL'})")
    for n in ns:
        for sigma in (0.5, 20.0):          # calibrated-tight vs sloppy band
            row = bench_risk_planning(n, reps=10 if n <= 10_000 else 3,
                                      sigma=sigma)
            risk_rows.append(row)
            print(f"risk   N={n:>6} s={sigma:>4}: point "
                  f"{row['point_ms']:8.3f} ms  risk {row['risk_ms']:8.3f} ms"
                  f" ({row['risk_overhead_x']:.2f}x, "
                  f"{row['deferred']}/{row['tasks']} deferred, "
                  f"invariant={'ok' if row['invariant_ok'] else 'FAIL'})")
    conf = bench_conformal()
    print(f"conformal: held-out coverage {conf['heldout_coverage']:.3f} "
          f"(nominal {conf['nominal']:.2f}, "
          f"q={conf['quantile_g_per_kwh']:.1f} g/kWh)")
    out = {"select": select_rows, "step": step_rows, "risk": risk_rows,
           "conformal": conf, "smoke": smoke,
           "paper_per_task_ms": PAPER_PER_TASK_MS}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    return out


def main(smoke: bool = False):
    return run(smoke=smoke)


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
