"""Beyond-paper: temporal load shifting (paper §V future work).

Evening-submitted deferrable workload vs run-now, diurnal (duck-curve)
intensity traces per region.
"""
from __future__ import annotations

from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.scheduler import MODES
from repro.core.temporal import (DeferrableTask, carbon_savings_from_deferral,
                                 synthetic_trace)


def run(deadlines=(0.5, 2.0, 8.0, 16.0, 24.0)):
    traces = {
        "node-high": synthetic_trace("coal-heavy", 620.0, solar_dip=0.1),
        "node-medium": synthetic_trace("cn-average", 530.0, solar_dip=0.3),
        "node-green": synthetic_trace("hydro-rich", 380.0, solar_dip=0.5),
    }
    rows = []
    for dl in deadlines:
        c = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
        c.profile(250.0)
        tasks = [DeferrableTask(cpu=0.05, mem_mb=16, deadline_hours=dl,
                                duration_hours=0.25) for _ in range(20)]
        out = carbon_savings_from_deferral(c, traces, MODES["green"], tasks,
                                           now_hour=19.0)
        rows.append({"deadline_h": dl, **out})
    return rows


def main():
    rows = run()
    print(f"{'deadline h':>10s} {'run-now g':>10s} {'deferred g':>11s} {'savings %':>10s}")
    for r in rows:
        print(f"{r['deadline_h']:10.1f} {r['run_now_g']:10.4f} "
              f"{r['deferred_g']:11.4f} {r['savings_pct']:10.1f}")
    return rows


if __name__ == "__main__":
    main()
