"""Paper Table IV: multi-model carbon footprint (V2 / V4 / B0)."""
from __future__ import annotations

from benchmarks import common

PAPER_REDUCTION = {"mobilenetv2": 22.9, "mobilenetv4": 14.8,
                   "efficientnet-b0": 32.2}


def run():
    out = {}
    for model in common.CALIBRATION:
        mono = common.run_monolithic(model)
        green = common.run_mode(model, "green")
        out[model] = {
            "mono_latency_ms": mono["totals"]["avg_latency_ms"],
            "mono_carbon": mono["totals"]["carbon_g_per_inf"],
            "green_latency_ms": green["totals"]["avg_latency_ms"],
            "green_carbon": green["totals"]["carbon_g_per_inf"],
            "reduction_pct": common.reduction_vs_mono(model, green, mono),
            "paper_reduction_pct": PAPER_REDUCTION[model],
        }
    return out


def main():
    out = run()
    print(f"{'model':16s} {'mono ms':>8s} {'mono g':>8s} {'green ms':>9s} "
          f"{'green g':>8s} {'red%':>6s} {'paper%':>7s}")
    for m, r in out.items():
        print(f"{m:16s} {r['mono_latency_ms']:8.2f} {r['mono_carbon']:8.5f} "
              f"{r['green_latency_ms']:9.2f} {r['green_carbon']:8.5f} "
              f"{r['reduction_pct']:6.1f} {r['paper_reduction_pct']:7.1f}")
    return out


if __name__ == "__main__":
    main()
