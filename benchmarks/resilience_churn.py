"""Resilience under churn: fault injection sweep + zero-fault overhead.

Three claims are measured (DESIGN.md §10) and asserted by
``gate_resilience``:

- **zero-fault bit-identity**: an engine with resilience attached but an
  empty fault schedule renders a byte-identical sim ``to_text`` to a
  resilience-free engine, on both execute paths; and a fixed fault seed
  reproduces a faulted run byte-identically;
- **overhead**: with resilience attached and no faults, the end-to-end
  ``engine.step`` stays within 1.1x of a bare engine at N=10^4, B=1024
  (interleaved timing, median of adjacent-pair ratios);
- **degraded-mode quality**: sweeping node churn rate x provider outage
  rate, the framework keeps serving — reporting request availability
  (completed / submitted), SLO violation rate, dead-letter counts, the
  schedule's MTTR, and the carbon regret of lagged failure detection
  against the fault-oracle run (same faults, zero detection lag — the
  scheduler that never places onto a dead node).

Writes ``BENCH_resilience.json``. The CI smoke runs ``run(smoke=True)``;
gate assertions live in ``benchmarks/ci_gates.py``
(``python -m benchmarks.ci_gates resilience``).
"""
from __future__ import annotations

import json
import time
from typing import Dict

from benchmarks.fleet_scale import make_fleet, make_tasks

OVERHEAD_ROW = (10_000, 1024)
OVERHEAD_BOUND_X = 1.1
AVAILABILITY_FLOOR = 0.95

# (crash_rate_per_hour, outage_rate_per_hour) sweep cells; the first is
# the baseline-churn cell the availability gate asserts on.
FULL_CELLS = ((1.0, 0.0), (1.0, 1.0), (3.0, 1.0), (8.0, 2.0))
SMOKE_CELLS = ((1.0, 0.0), (8.0, 2.0))


def _sim(faults=None, *, resilient: bool = True, n_nodes: int = 6,
         horizon: float = 0.5, seed: int = 11,
         batch_execute: bool = True):
    """One churn sim: Poisson arrivals over a small heterogeneous fleet
    with out-of-phase diurnal intensity traces (time-varying, so delayed
    or re-placed work has a real carbon cost), resilience + the
    last-known-good provider wired, faults optional."""
    import numpy as np

    from repro.core.api import CarbonEdgeEngine, TraceProvider
    from repro.core.cluster import EdgeCluster, NodeSpec
    from repro.core.scheduler import Task
    from repro.core.temporal import IntensityTrace
    from repro.resilience import Resilience, ResilientProvider
    from repro.sim import AsyncEngineDriver, PoissonArrivals

    c = EdgeCluster(nodes=[])
    hours24 = np.arange(24.0)
    traces = {}
    for i in range(n_nodes):
        c.add_node(NodeSpec(f"n{i}", cpu=2.0, mem_mb=16000.0,
                            carbon_intensity=80.0 + 55.0 * i))
        vals = 80.0 + 55.0 * i + 60.0 * np.sin(
            2.0 * np.pi * (hours24 / 24.0 + i / n_nodes))
        traces[f"n{i}"] = IntensityTrace(
            f"r{i}", tuple(float(v) for v in vals))
    base = TraceProvider(traces)
    provider = ResilientProvider(base) if resilient else base
    if resilient:
        # seed the last-known-good cache so a blackout at hour 0 degrades
        # instead of KeyError-ing
        provider.intensity_batch(list(c.nodes), 0.0)
    res = Resilience(max_attempts=4, backoff_base_hours=0.005) \
        if resilient else None
    eng = CarbonEdgeEngine(c, provider=provider, resilience=res,
                           batch_execute=batch_execute)
    drv = AsyncEngineDriver(
        eng, PoissonArrivals(rate_per_hour=400.0, seed=seed),
        lambda uid, hour: Task(cpu=0.1, mem_mb=64.0, base_latency_ms=60.0),
        horizon_hours=horizon, max_batch=16, slo_latency_s=1.0,
        faults=faults)
    m = drv.run()
    return m, eng


def _make_faults(n_nodes: int, horizon: float, crash_rate: float,
                 outage_rate: float, seed: int):
    from repro.resilience import FaultInjector

    return FaultInjector.generate(
        [f"n{i}" for i in range(n_nodes)], horizon, seed=seed,
        crash_rate_per_hour=crash_rate, mttr_hours=0.06,
        detect_delay_hours=0.02,
        outage_rate_per_hour=outage_rate, outage_hours=0.08,
        straggle_rate_per_hour=crash_rate / 2.0, straggle_hours=0.05)


def churn_cell(crash_rate: float, outage_rate: float, *,
               n_nodes: int = 8, horizon: float = 0.5,
               seed: int = 3) -> Dict:
    """One sweep cell: the lagged-detection run vs its fault oracle."""
    inj = _make_faults(n_nodes, horizon, crash_rate, outage_rate, seed)
    m, eng = _sim(inj, n_nodes=n_nodes, horizon=horizon)
    s = m.summary()
    dead = sum(m.dead.values())
    submitted = s["tasks"] + dead
    # oracle: identical fault windows, zero detection lag (fresh injector
    # — one injector carries restore state for exactly one run)
    oracle_inj = _make_faults(n_nodes, horizon, crash_rate, outage_rate,
                              seed).without_detection_lag()
    mo, _ = _sim(oracle_inj, n_nodes=n_nodes, horizon=horizon)
    so = mo.summary()
    oracle_per_task = (so["carbon_g_per_task"] if so["tasks"] else 0.0)
    regret = (s["carbon_g_per_task"] / oracle_per_task - 1.0
              if oracle_per_task else 0.0)
    return {
        "crash_rate_per_hour": crash_rate,
        "outage_rate_per_hour": outage_rate,
        "fleet_availability": inj.fleet_availability(n_nodes, horizon),
        "request_availability": (s["tasks"] / submitted
                                 if submitted else 1.0),
        "completed": s["tasks"],
        "dead_letters": dead,
        "retries_total": eng.report()["outcomes"].get("retry", 0),
        "slo_violation_rate": s["slo_violation_rate"],
        "mttr_hours": inj.mttr_hours(),
        "carbon_g_per_task": s["carbon_g_per_task"],
        "oracle_carbon_g_per_task": oracle_per_task,
        "carbon_regret_vs_oracle": regret,
        "contact_failures": sum(
            eng.resilience.health.fails_total.values()),
    }


def byte_identity() -> Dict:
    """Zero-fault schedule -> byte-identical to a resilience-free run on
    both execute paths; fixed fault seed -> byte-identical repeats."""
    from repro.resilience import FaultInjector

    out = {}
    for batch_execute in (True, False):
        key = "batched" if batch_execute else "scalar"
        golden = _sim(None, resilient=False,
                      batch_execute=batch_execute)[0].to_text()
        wired = _sim(FaultInjector.scripted([]), resilient=True,
                     batch_execute=batch_execute)[0].to_text()
        out[f"{key}_zero_fault_match"] = wired == golden
    a = _sim(_make_faults(8, 0.5, 3.0, 1.0, 7))[0].to_text()
    b = _sim(_make_faults(8, 0.5, 3.0, 1.0, 7))[0].to_text()
    out["fault_seed_repeat_match"] = a == b
    return out


def bench_overhead(n: int, b: int, *, reps: int, seed: int = 0) -> Dict:
    """Interleaved zero-fault ``engine.step``: resilience attached vs
    bare. Median of adjacent-pair ratios (same estimator as the obs
    gate) — each pair runs back-to-back under the same machine state."""
    from repro.core.api import CarbonEdgeEngine
    from repro.resilience import Resilience

    eng_off = CarbonEdgeEngine(make_fleet(n, seed=seed))
    eng_on = CarbonEdgeEngine(make_fleet(n, seed=seed),
                              resilience=Resilience())
    tasks = make_tasks(b, seed=seed)
    eng_off.submit_many(tasks)
    off_nodes = [r.node for r in eng_off.step()]   # warm (caches, memo)
    eng_on.submit_many(tasks)
    on_nodes = [r.node for r in eng_on.step()]
    assert on_nodes == off_nodes, \
        "attached resilience changed a zero-fault scheduling decision"
    offs, ons = [], []
    for _ in range(reps):
        eng_off.submit_many(tasks)
        t0 = time.perf_counter()
        eng_off.step()
        offs.append(time.perf_counter() - t0)
        eng_on.submit_many(tasks)
        t0 = time.perf_counter()
        eng_on.step()
        ons.append(time.perf_counter() - t0)
    pair = sorted(on / off for on, off in zip(ons, offs))
    return {
        "n_nodes": n, "batch": b, "reps": reps,
        "bare_step_ms": min(offs) * 1e3,
        "resilient_step_ms": min(ons) * 1e3,
        "overhead_x": pair[len(pair) // 2],
        "overhead_best_x": min(ons) / min(offs),
    }


def run(smoke: bool = False,
        out_path: str = "BENCH_resilience.json") -> Dict:
    cells = []
    for crash_rate, outage_rate in (SMOKE_CELLS if smoke else FULL_CELLS):
        cell = churn_cell(crash_rate, outage_rate)
        cells.append(cell)
        print(f"churn {crash_rate:4.1f}/h outage {outage_rate:4.1f}/h: "
              f"avail {cell['request_availability']:.4f} "
              f"slo_viol {cell['slo_violation_rate']:.4f} "
              f"dead {cell['dead_letters']:3d} "
              f"mttr {cell['mttr_hours']*60:5.1f} min "
              f"regret {cell['carbon_regret_vs_oracle']:+.4f}")
    identity = byte_identity()
    print("byte-identity:", identity)
    n, b = OVERHEAD_ROW
    overhead = bench_overhead(n, b, reps=20 if smoke else 40)
    print(f"overhead N={n} B={b}: bare {overhead['bare_step_ms']:.3f} ms "
          f"resilient {overhead['resilient_step_ms']:.3f} ms "
          f"({overhead['overhead_x']:.3f}x)")
    out = {"cells": cells, "byte_identity": identity,
           "overhead": overhead, "smoke": smoke,
           "overhead_bound_x": OVERHEAD_BOUND_X,
           "availability_floor": AVAILABILITY_FLOOR}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    return out


def main(smoke: bool = False):
    return run(smoke=smoke)


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
