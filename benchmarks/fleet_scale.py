"""Fleet-scale scheduling fast path: per-step latency at N up to 10^5+.

Sweeps fleet size N x batch size B and times one engine scheduling
decision (``VectorizedPolicy.select_batch``) through three paths:

- **legacy** — the rebuild-everything path: fresh ``featurize`` (O(N)
  Python per-node loop + N provider calls) per step (``use_cache=False``);
- **cached** — the incremental FeatureCache fast path (DESIGN.md §3):
  O(changed) sync, one batched provider read, task-profile dedup, chunked
  vectorized scoring;
- **plan_wake** — deferral planning over the (S, N) slot grid, scalar
  nodes x slots loop vs the batched grid read.

Reports per-step latency, scheduled tasks/sec, and per-task overhead vs
the paper's 0.03 ms claim, and writes ``BENCH_fleet_scale.json``. The CI
smoke runs a reduced sweep (`run(smoke=True)`) and gates on a >2x
per-task-overhead regression.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np

from repro.core.api import StaticProvider
from repro.core.cluster import EdgeCluster, NodeSpec
from repro.core.policy import VectorizedPolicy
from repro.core.scheduler import MODES, Task
from repro.core.temporal import (DeferrableTask, plan_wake, plan_wake_scalar,
                                 synthetic_trace)
from repro.core.api import TraceProvider

PAPER_PER_TASK_MS = 0.03

FULL_NS = (1_000, 10_000, 100_000)
FULL_BS = (64, 256, 1024)
SMOKE_NS = (512, 2_048)
SMOKE_BS = (64,)


def make_fleet(n: int, seed: int = 0) -> EdgeCluster:
    rng = np.random.default_rng(seed)
    nodes = [NodeSpec(f"n{i}", cpu=float(rng.uniform(0.1, 4.0)),
                      mem_mb=int(rng.integers(128, 4096)),
                      carbon_intensity=float(rng.uniform(10.0, 1200.0)))
             for i in range(n)]
    c = EdgeCluster(nodes=nodes, host_power_w=142.0)
    c.profile(250.0)
    loads = rng.uniform(0.0, 0.9, n)
    for st, ld in zip(c.nodes.values(), loads):
        st.load = float(ld)
    return c


def make_tasks(b: int, seed: int = 0) -> List[Task]:
    # a handful of distinct resource profiles, like a real request mix —
    # exercises (rather than trivially defeats) the dedup fast path
    rng = np.random.default_rng(seed)
    profiles = [(float(rng.uniform(0.01, 0.5)), float(rng.uniform(8.0, 128.0)))
                for _ in range(8)]
    return [Task(cpu=c, mem_mb=m, base_latency_ms=250.0)
            for c, m in (profiles[i % len(profiles)] for i in range(b))]


def _time(fn, reps: int) -> float:
    """Best-of-reps wall time: the min is robust to scheduler/GC noise
    (what we want for a per-step latency claim)."""
    fn()                                   # warm (jit, cache build)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_select(cluster: EdgeCluster, tasks: List[Task], *,
                 legacy_reps: int, cached_reps: int) -> Dict:
    w = MODES["green"]
    provider = StaticProvider.from_cluster(cluster)
    legacy = VectorizedPolicy(backend="numpy", use_cache=False)
    cached = VectorizedPolicy(backend="numpy", use_cache=True)
    # dirty a handful of nodes between steps, like a live engine would
    names = list(cluster.nodes)
    def step_cached():
        for nm in names[:8]:
            cluster.nodes[nm].running += 1
            cluster.nodes[nm].running -= 1
        return cached.select_batch(cluster, tasks, w, provider)
    legacy_s = _time(lambda: legacy.select_batch(cluster, tasks, w, provider),
                     legacy_reps)
    cached_s = _time(step_cached, cached_reps)
    assert (cached.select_batch(cluster, tasks, w, provider)
            == legacy.select_batch(cluster, tasks, w, provider)), \
        "cached fast path diverged from the fresh-featurize oracle"
    b = len(tasks)
    return {
        "n_nodes": len(names), "batch": b,
        "legacy_step_ms": legacy_s * 1e3,
        "cached_step_ms": cached_s * 1e3,
        "speedup_x": legacy_s / cached_s,
        "cached_per_task_ms": cached_s * 1e3 / b,
        "cached_tasks_per_sec": b / cached_s,
        "paper_per_task_ms": PAPER_PER_TASK_MS,
        "vs_paper_x": (cached_s * 1e3 / b) / PAPER_PER_TASK_MS,
    }


def bench_plan_wake(cluster: EdgeCluster, *, reps: int) -> Dict:
    traces = {nm: synthetic_trace(nm, st.spec.carbon_intensity,
                                  seed=i % 16)
              for i, (nm, st) in enumerate(cluster.nodes.items())}
    provider = TraceProvider(traces)
    task = DeferrableTask(cpu=0.05, mem_mb=16.0, deadline_hours=12.0,
                          duration_hours=0.5)
    scalar_s = _time(lambda: plan_wake_scalar(provider, cluster, task, 17.0),
                     max(1, reps // 4))
    batched_s = _time(lambda: plan_wake(provider, cluster, task, 17.0), reps)
    assert plan_wake(provider, cluster, task, 17.0) == \
        plan_wake_scalar(provider, cluster, task, 17.0)
    return {
        "n_nodes": len(cluster.nodes),
        "scalar_ms": scalar_s * 1e3,
        "batched_ms": batched_s * 1e3,
        "speedup_x": scalar_s / batched_s,
    }


def run(smoke: bool = False, out_path: str = "BENCH_fleet_scale.json") -> Dict:
    ns = SMOKE_NS if smoke else FULL_NS
    bs = SMOKE_BS if smoke else FULL_BS
    select_rows, wake_rows = [], []
    for n in ns:
        cluster = make_fleet(n)
        # the fresh-featurize baseline is O(N) Python — keep its reps tiny
        # at fleet scale so the benchmark itself stays tractable
        legacy_reps = 5 if n <= 2_000 else (2 if n <= 10_000 else 1)
        cached_reps = 50 if n <= 2_000 else (20 if n <= 10_000 else 5)
        for b in bs:
            row = bench_select(cluster, make_tasks(b),
                               legacy_reps=legacy_reps,
                               cached_reps=cached_reps)
            select_rows.append(row)
            print(f"select N={n:>7} B={b:>5}: legacy {row['legacy_step_ms']:9.2f} ms"
                  f"  cached {row['cached_step_ms']:7.3f} ms"
                  f"  ({row['speedup_x']:7.1f}x, "
                  f"{row['cached_per_task_ms']*1e3:7.2f} us/task,"
                  f" paper budget {PAPER_PER_TASK_MS*1e3:.0f} us)")
        wake = bench_plan_wake(cluster, reps=20 if n <= 10_000 else 5)
        wake_rows.append(wake)
        print(f"plan_wake N={n:>7}: scalar {wake['scalar_ms']:9.2f} ms"
              f"  batched {wake['batched_ms']:7.3f} ms"
              f"  ({wake['speedup_x']:7.1f}x)")
    out = {"select": select_rows, "plan_wake": wake_rows,
           "smoke": smoke, "paper_per_task_ms": PAPER_PER_TASK_MS}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    return out


def main(smoke: bool = False):
    return run(smoke=smoke)


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
