"""Fleet-scale scheduling fast path: per-step latency at N up to 10^5+.

Sweeps fleet size N x batch size B and times one engine scheduling
decision (``VectorizedPolicy.select_batch``) through three paths:

- **legacy** — the rebuild-everything path: fresh ``featurize`` (O(N)
  Python per-node loop + N provider calls) per step (``use_cache=False``);
- **cached** — the incremental FeatureCache fast path (DESIGN.md §3):
  O(changed) sync, one batched provider read, task-profile dedup, chunked
  vectorized scoring (selection memo off, so the rows keep measuring the
  scoring pass itself);
- **plan_wake** — deferral planning over the (S, N) slot grid, scalar
  nodes x slots loop vs the batched grid read;
- **step** — the END-TO-END ``CarbonEdgeEngine.step`` (select + execute +
  bill, DESIGN.md §6): the production default (batched execution +
  selection memo) vs the per-task execute loop (``batch_execute=False``),
  so the paper's 0.03 ms/task budget is measured for the whole step, not
  just selection.

Reports per-step latency, scheduled tasks/sec, and per-task overhead vs
the paper's 0.03 ms claim, and writes ``BENCH_fleet_scale.json``. The CI
smoke runs a reduced sweep (`run(smoke=True)`); the gate assertions live
in ``benchmarks/ci_gates.py`` (runnable locally:
``python -m benchmarks.ci_gates fleet``).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np

from repro.core.api import StaticProvider
from repro.core.cluster import EdgeCluster, NodeSpec
from repro.core.policy import VectorizedPolicy
from repro.core.scheduler import MODES, Task
from repro.core.temporal import (DeferrableTask, plan_wake, plan_wake_scalar,
                                 synthetic_trace)
from repro.core.api import TraceProvider

PAPER_PER_TASK_MS = 0.03

FULL_NS = (1_000, 10_000, 100_000)
FULL_BS = (64, 256, 1024)
SMOKE_NS = (512, 2_048)
SMOKE_BS = (64,)


def make_fleet(n: int, seed: int = 0) -> EdgeCluster:
    rng = np.random.default_rng(seed)
    nodes = [NodeSpec(f"n{i}", cpu=float(rng.uniform(0.1, 4.0)),
                      mem_mb=int(rng.integers(128, 4096)),
                      carbon_intensity=float(rng.uniform(10.0, 1200.0)))
             for i in range(n)]
    c = EdgeCluster(nodes=nodes, host_power_w=142.0)
    c.profile(250.0)
    loads = rng.uniform(0.0, 0.9, n)
    for st, ld in zip(c.nodes.values(), loads):
        st.load = float(ld)
    return c


def make_tasks(b: int, seed: int = 0) -> List[Task]:
    # a handful of distinct resource profiles, like a real request mix —
    # exercises (rather than trivially defeats) the dedup fast path
    rng = np.random.default_rng(seed)
    profiles = [(float(rng.uniform(0.01, 0.5)), float(rng.uniform(8.0, 128.0)))
                for _ in range(8)]
    return [Task(cpu=c, mem_mb=m, base_latency_ms=250.0)
            for c, m in (profiles[i % len(profiles)] for i in range(b))]


def _time(fn, reps: int) -> float:
    """Best-of-reps wall time: the min is robust to scheduler/GC noise
    (what we want for a per-step latency claim)."""
    fn()                                   # warm (jit, cache build)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_select(cluster: EdgeCluster, tasks: List[Task], *,
                 legacy_reps: int, cached_reps: int) -> Dict:
    w = MODES["green"]
    provider = StaticProvider.from_cluster(cluster)
    legacy = VectorizedPolicy(backend="numpy", use_cache=False)
    # memo off: these rows measure the incremental-featurize scoring pass,
    # not the steady-state profile memo (bench_step measures that)
    cached = VectorizedPolicy(backend="numpy", use_cache=True,
                              use_select_memo=False)
    # dirty a handful of nodes between steps, like a live engine would
    names = list(cluster.nodes)
    def step_cached():
        for nm in names[:8]:
            cluster.nodes[nm].running += 1
            cluster.nodes[nm].running -= 1
        return cached.select_batch(cluster, tasks, w, provider)
    legacy_s = _time(lambda: legacy.select_batch(cluster, tasks, w, provider),
                     legacy_reps)
    cached_s = _time(step_cached, cached_reps)
    assert (cached.select_batch(cluster, tasks, w, provider)
            == legacy.select_batch(cluster, tasks, w, provider)), \
        "cached fast path diverged from the fresh-featurize oracle"
    b = len(tasks)
    return {
        "n_nodes": len(names), "batch": b,
        "legacy_step_ms": legacy_s * 1e3,
        "cached_step_ms": cached_s * 1e3,
        "speedup_x": legacy_s / cached_s,
        "cached_per_task_ms": cached_s * 1e3 / b,
        "cached_tasks_per_sec": b / cached_s,
        "paper_per_task_ms": PAPER_PER_TASK_MS,
        "vs_paper_x": (cached_s * 1e3 / b) / PAPER_PER_TASK_MS,
    }


def bench_step(n: int, b: int, *, scalar_reps: int, batched_reps: int,
               seed: int = 0) -> Dict:
    """End-to-end ``engine.step`` (select + execute + bill) per-task time:
    the batched execution path (engine default) vs the per-task execute
    loop it replaced (``batch_execute=False``). Each path gets its own
    fresh engine so ledgers and caches are comparable; ledger parity
    between the two paths is asserted exactly."""
    from repro.core.api import CarbonEdgeEngine

    def run_path(batch_execute: bool, reps: int) -> float:
        eng = CarbonEdgeEngine(make_fleet(n, seed=seed),
                               batch_execute=batch_execute)
        tasks = make_tasks(b, seed=seed)
        eng.submit_many(tasks)
        eng.step()                         # warm (cache build, memo fill)
        best = float("inf")
        for _ in range(reps):
            eng.submit_many(tasks)
            t0 = time.perf_counter()
            eng.step()
            best = min(best, time.perf_counter() - t0)
        return best

    scalar_s = run_path(False, scalar_reps)
    batched_s = run_path(True, batched_reps)
    # bit-exact parity of the two execution paths on identical traffic
    ea = CarbonEdgeEngine(make_fleet(n, seed=seed), batch_execute=False)
    eb = CarbonEdgeEngine(make_fleet(n, seed=seed), batch_execute=True)
    tasks = make_tasks(b, seed=seed)
    ra = ea.submit_many(tasks).step()
    rb = eb.submit_many(tasks).step()
    assert ra == rb and ea.cluster.log == eb.cluster.log \
        and ea.monitor.report() == eb.monitor.report(), \
        "batched execution diverged from the per-task loop"
    return {
        "n_nodes": n, "batch": b,
        "scalar_step_ms": scalar_s * 1e3,
        "batched_step_ms": batched_s * 1e3,
        "speedup_x": scalar_s / batched_s,
        "scalar_per_task_ms": scalar_s * 1e3 / b,
        "batched_per_task_ms": batched_s * 1e3 / b,
        "batched_tasks_per_sec": b / batched_s,
        "paper_per_task_ms": PAPER_PER_TASK_MS,
        "vs_paper_x": (batched_s * 1e3 / b) / PAPER_PER_TASK_MS,
    }


def bench_plan_wake(cluster: EdgeCluster, *, reps: int) -> Dict:
    traces = {nm: synthetic_trace(nm, st.spec.carbon_intensity,
                                  seed=i % 16)
              for i, (nm, st) in enumerate(cluster.nodes.items())}
    provider = TraceProvider(traces)
    task = DeferrableTask(cpu=0.05, mem_mb=16.0, deadline_hours=12.0,
                          duration_hours=0.5)
    scalar_s = _time(lambda: plan_wake_scalar(provider, cluster, task, 17.0),
                     max(1, reps // 4))
    batched_s = _time(lambda: plan_wake(provider, cluster, task, 17.0), reps)
    assert plan_wake(provider, cluster, task, 17.0) == \
        plan_wake_scalar(provider, cluster, task, 17.0)
    return {
        "n_nodes": len(cluster.nodes),
        "scalar_ms": scalar_s * 1e3,
        "batched_ms": batched_s * 1e3,
        "speedup_x": scalar_s / batched_s,
    }


def run(smoke: bool = False, out_path: str = "BENCH_fleet_scale.json") -> Dict:
    ns = SMOKE_NS if smoke else FULL_NS
    bs = SMOKE_BS if smoke else FULL_BS
    select_rows, wake_rows = [], []
    for n in ns:
        cluster = make_fleet(n)
        # the fresh-featurize baseline is O(N) Python — keep its reps tiny
        # at fleet scale so the benchmark itself stays tractable
        legacy_reps = 5 if n <= 2_000 else (2 if n <= 10_000 else 1)
        cached_reps = 50 if n <= 2_000 else (20 if n <= 10_000 else 5)
        for b in bs:
            row = bench_select(cluster, make_tasks(b),
                               legacy_reps=legacy_reps,
                               cached_reps=cached_reps)
            select_rows.append(row)
            print(f"select N={n:>7} B={b:>5}: legacy {row['legacy_step_ms']:9.2f} ms"
                  f"  cached {row['cached_step_ms']:7.3f} ms"
                  f"  ({row['speedup_x']:7.1f}x, "
                  f"{row['cached_per_task_ms']*1e3:7.2f} us/task,"
                  f" paper budget {PAPER_PER_TASK_MS*1e3:.0f} us)")
        wake = bench_plan_wake(cluster, reps=20 if n <= 10_000 else 5)
        wake_rows.append(wake)
        print(f"plan_wake N={n:>7}: scalar {wake['scalar_ms']:9.2f} ms"
              f"  batched {wake['batched_ms']:7.3f} ms"
              f"  ({wake['speedup_x']:7.1f}x)")
    step_rows = []
    for n in ns:
        b = max(bs) if not smoke else 256
        row = bench_step(n, b,
                         scalar_reps=5 if n <= 10_000 else 2,
                         batched_reps=20 if n <= 10_000 else 5)
        step_rows.append(row)
        print(f"step e2e N={n:>7} B={b:>5}: scalar-exec "
              f"{row['scalar_step_ms']:9.2f} ms  batched "
              f"{row['batched_step_ms']:7.3f} ms  ({row['speedup_x']:5.1f}x,"
              f" {row['batched_per_task_ms']*1e3:7.2f} us/task,"
              f" paper budget {PAPER_PER_TASK_MS*1e3:.0f} us)")
    out = {"select": select_rows, "plan_wake": wake_rows, "step": step_rows,
           "smoke": smoke, "paper_per_task_ms": PAPER_PER_TASK_MS}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    return out


def main(smoke: bool = False):
    return run(smoke=smoke)


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
