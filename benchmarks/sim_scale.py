"""Internet-scale simulation benchmark (DESIGN.md §11).

Measures the array-based :class:`~repro.sim.EventCalendar` driver loop
against the scalar :class:`~repro.sim.EventHeap` oracle it replaced, and
pins the determinism contracts the refactor must keep:

- **byte_identity** — a nontrivial real-engine scenario (PAPER_NODES
  fleet, mixed open-loop Poisson arrivals + closed-loop tenant
  populations with SLO/retry/backoff, intensity ticks) renders a
  byte-identical ``metrics.to_text()`` across *all four* combinations of
  ``event_queue`` x ``batch_execute``.
- **replay** — per-event cost of the event machinery itself, measured
  with a constant-cost null executor so the engine's scoring/execute
  work (unchanged by this PR) doesn't mask the loop being measured: a
  precomputed arrival schedule replayed through heap vs calendar, wall
  clock, per-event microseconds, speedup and peak RSS. This is the
  headline >=10x surface: with every event staged before the first pop
  the calendar drains long same-kind array runs.
- **closed_loop** — the same measurement on a closed-loop tenant
  scenario (think/SLO/retry/backoff). Each batch drain re-arms at most
  one window-flush timer, so the oracle semantics themselves fragment
  runs to the inter-flush spacing and the speedup is structurally
  smaller; the gate asserts byte identity plus a loose floor here.
  Heap-vs-calendar byte identity is asserted on every row of both
  sections as a free side effect.
- **trace_replay** — a day-long multi-region ElectricityMaps-style CSV
  is synthesized, ingested via :meth:`TraceProvider.from_csv`, and a
  24 h sim over it must be byte-deterministic across a repeat run, both
  event queues and both execute paths.

Smoke mode (the ``sim_scale`` CI gate) sizes the rows at ~2*10^4 and
~10^5 processed events; the full sweep (``--full``) adds the acceptance
rows — a heap-vs-calendar byte-identity replay at 10^7 events, then
10^6 closed-loop clients (~10^7 events) through the calendar.

    PYTHONPATH=src:. python -m benchmarks.sim_scale [--full]
"""
from __future__ import annotations

import gc
import json
import resource
from contextlib import contextmanager
from time import perf_counter

import numpy as np

from repro.core.api import CarbonEdgeEngine, TraceProvider
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.scheduler import Task
from repro.sim import (AsyncEngineDriver, ClientPopulation,
                       ClosedLoopClientPool, PoissonArrivals,
                       TraceReplayArrivals)

SEED = 20260808
TASK = Task(cpu=0.05, mem_mb=16.0, base_latency_ms=250.0)
CSV_ZONES = ("DE", "FR", "PL")


# ---------------------------------------------------------------------------
# Null executor: constant-cost step, so the rows measure the event loop
# ---------------------------------------------------------------------------


class _NullResult:
    """Shared constant result; the driver only reads these attributes.
    Service time is small enough that the benchmark fleet stays
    unsaturated — the regime where long same-kind runs exist for the
    calendar to batch (the saturated regime degrades both queues to
    one event per batch and is covered by the tests, not timed here)."""
    __slots__ = ()
    latency_ms = 0.05
    energy_kwh = 1e-6
    carbon_g = 0.5
    node = "n0"


class NullExecutor:
    """O(1)-per-step executor with fixed per-task cost.

    Isolates the quantity this benchmark gates — driver/event-queue
    overhead per event — from the engine's scoring and billing work,
    which dominates wall clock in a real scenario and is unchanged by
    the calendar refactor. Exposes the same surface the driver uses on
    a real engine: ``submit``/``submit_many``/``step`` plus the
    ``last_exec`` column snapshot, with the snapshot carrying exactly
    the floats the result objects do (so heap and calendar runs stay
    byte-identical).
    """

    def __init__(self, max_batch: int):
        self._queued = 0
        self._res = _NullResult()
        self._uniq = np.array([_NullResult.node])
        self._inv = np.zeros(max_batch, dtype=np.int64)
        self._lat = np.full(max_batch, _NullResult.latency_ms)
        self._ekwh = np.full(max_batch, _NullResult.energy_kwh)
        self._cg = np.full(max_batch, _NullResult.carbon_g)
        self.last_exec = None

    def submit(self, task) -> None:
        self._queued += 1

    def submit_many(self, tasks) -> None:
        self._queued += len(tasks)

    def step(self, now_hour: float = 0.0, limit=None):
        k = self._queued if limit is None else min(self._queued, limit)
        self._queued -= k
        self.last_exec = (self._uniq, self._inv[:k], self._lat[:k],
                          self._ekwh[:k], self._cg[:k])
        return [self._res] * k


def _null_driver(n_clients: int, horizon_hours: float,
                 event_queue: str, max_batch: int = 256) -> AsyncEngineDriver:
    """Closed-loop scenario against the null executor: a bulk tenant that
    always meets its SLO and a strict tenant that never does (its SLO is
    below the constant service time), so the run exercises first tries,
    retries, backoff and abandonment deterministically."""
    n_bulk = (n_clients * 4) // 5
    pool = ClosedLoopClientPool([
        ClientPopulation("bulk", n_bulk, mean_think_hours=0.02),
        ClientPopulation("strict", n_clients - n_bulk,
                         mean_think_hours=0.03, slo_latency_s=1e-5,
                         max_attempts=3, priority=1),
    ], seed=SEED)
    return AsyncEngineDriver(
        NullExecutor(max_batch), None, lambda uid, hour, tenant: uid,
        horizon_hours=horizon_hours, max_batch=max_batch,
        batch_window_hours=5e-4, clients=pool, event_queue=event_queue)


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


@contextmanager
def _nogc():
    """Cyclic GC off around a timed run (both queues get the identical
    treatment). At 10^7 staged events every gen2 collection walks the
    whole live population, which turns the *heap* run superlinear —
    refcounting still frees popped events, so disabling the collector
    only removes scan time. The heap benefits far more than the
    calendar (whose events are rows in a handful of arrays), so the
    reported speedups are conservative."""
    was = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        yield
    finally:
        if was:
            gc.enable()


def _replay_driver(arrival_hours: np.ndarray, horizon_hours: float,
                   event_queue: str,
                   max_batch: int = 1024) -> AsyncEngineDriver:
    """Open-loop replay of a precomputed arrival schedule — the pure
    array-drain case (every event is staged before the first pop), so a
    large ``max_batch`` lets the calendar amortize its fixed per-batch
    numpy cost over long same-kind runs."""
    return AsyncEngineDriver(
        NullExecutor(max_batch), TraceReplayArrivals(arrival_hours),
        lambda uid, hour: uid, horizon_hours=horizon_hours,
        max_batch=max_batch, batch_window_hours=5e-4,
        event_queue=event_queue)


def bench_replay(n_arrivals: int, heap_oracle: bool = True) -> dict:
    """One replay row: the same recorded schedule through both queues."""
    rng = np.random.default_rng(SEED + 2)
    horizon = n_arrivals / 600_000.0          # ~600k arrivals per sim-hour
    ts = np.sort(rng.uniform(0.0, horizon, n_arrivals))
    runs = {}
    for q in (("calendar", "heap") if heap_oracle else ("calendar",)):
        drv = _replay_driver(ts, horizon, q)
        with _nogc():
            t0 = perf_counter()
            m = drv.run()
            wall = perf_counter() - t0
        runs[q] = {"wall_s": wall, "events": drv.events_processed,
                   "text": m.to_text() if heap_oracle else None,
                   "tasks": m.n_records}
    cal = runs["calendar"]
    assert cal["tasks"] == n_arrivals, (cal["tasks"], n_arrivals)
    row = {
        "n_arrivals": n_arrivals,
        "events": cal["events"],
        "calendar_wall_s": round(cal["wall_s"], 4),
        "calendar_per_event_us": round(cal["wall_s"] / cal["events"] * 1e6,
                                       4),
        "peak_rss_mb": round(_peak_rss_mb(), 1),
    }
    if heap_oracle:
        heap = runs["heap"]
        assert heap["events"] == cal["events"]
        row["heap_wall_s"] = round(heap["wall_s"], 4)
        row["heap_per_event_us"] = round(
            heap["wall_s"] / heap["events"] * 1e6, 4)
        row["speedup_x"] = round(row["heap_per_event_us"]
                                 / row["calendar_per_event_us"], 2)
        row["byte_identity"] = heap["text"] == cal["text"]
    return row


def bench_row(n_clients: int, horizon_hours: float,
              heap_oracle: bool = True) -> dict:
    """One speedup row: same scenario through both queues (heap skipped
    at full scale, where the scalar loop would take minutes)."""
    runs = {}
    for q in (("calendar", "heap") if heap_oracle else ("calendar",)):
        drv = _null_driver(n_clients, horizon_hours, q)
        with _nogc():
            t0 = perf_counter()
            m = drv.run()
            wall = perf_counter() - t0
        runs[q] = {"wall_s": wall, "events": drv.events_processed,
                   "tasks": m.n_records,
                   "text": m.to_text() if heap_oracle else None,
                   "summary": m.summary()}
    cal = runs["calendar"]
    row = {
        "n_clients": n_clients,
        "horizon_hours": horizon_hours,
        "events": cal["events"],
        "tasks": cal["tasks"],
        "calendar_wall_s": round(cal["wall_s"], 4),
        "calendar_per_event_us": round(cal["wall_s"] / cal["events"] * 1e6,
                                       4),
        "peak_rss_mb": round(_peak_rss_mb(), 1),
    }
    if heap_oracle:
        heap = runs["heap"]
        assert heap["events"] == cal["events"], (heap["events"],
                                                 cal["events"])
        row["heap_wall_s"] = round(heap["wall_s"], 4)
        row["heap_per_event_us"] = round(
            heap["wall_s"] / heap["events"] * 1e6, 4)
        row["speedup_x"] = round(row["heap_per_event_us"]
                                 / row["calendar_per_event_us"], 2)
        row["byte_identity"] = heap["text"] == cal["text"]
    return row


# ---------------------------------------------------------------------------
# Real-engine byte identity: event_queue x batch_execute
# ---------------------------------------------------------------------------


def _engine_driver(event_queue: str, batch_execute: bool,
                   provider=None, horizon_hours: float = 0.12,
                   tick_hours: float = 0.05) -> AsyncEngineDriver:
    cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    cluster.profile(250.0)
    engine = CarbonEdgeEngine(cluster, mode="green", provider=provider,
                              batch_execute=batch_execute)
    pool = ClosedLoopClientPool([
        ClientPopulation("interactive", 180, mean_think_hours=0.01,
                         slo_latency_s=2.0, max_attempts=3, priority=1),
        ClientPopulation("batch", 120, mean_think_hours=0.02),
    ], seed=SEED + 1)
    return AsyncEngineDriver(
        engine, PoissonArrivals(200.0, seed=3),
        lambda uid, hour, tenant: TASK,
        horizon_hours=horizon_hours, max_batch=16,
        batch_window_hours=0.002, tick_hours=tick_hours, clients=pool,
        slo_latency_s=2.0, event_queue=event_queue)


def engine_identity() -> dict:
    """All four event_queue x batch_execute combinations must render the
    same metrics text byte for byte (the heap-oracle contract)."""
    texts = {}
    for q in ("heap", "calendar"):
        for be in (True, False):
            m = _engine_driver(q, be).run()
            texts[f"{q}_batchexec_{be}"] = m.to_text()
    ref_key, ref = "heap_batchexec_True", texts["heap_batchexec_True"]
    return {key: (texts[key] == ref) for key in texts if key != ref_key}


# ---------------------------------------------------------------------------
# Multi-region CSV trace replay
# ---------------------------------------------------------------------------


def synth_csv(n_hours: int = 24) -> str:
    """A day-long ElectricityMaps-style export: one row per
    (timestamp, zone), deterministic diurnal shapes per zone."""
    bases = {"DE": 320.0, "FR": 60.0, "PL": 710.0}
    amps = {"DE": 120.0, "FR": 15.0, "PL": 90.0}
    lines = ["datetime,zone_name,carbon_intensity_avg"]
    for h in range(n_hours):
        for z in CSV_ZONES:
            v = bases[z] - amps[z] * np.sin((h - 6.0) / 24.0 * 2 * np.pi)
            lines.append(f"2026-08-07T{h:02d}:00:00Z,{z},{v:.3f}")
    return "\n".join(lines) + "\n"


def trace_replay() -> dict:
    """24 h sim over the ingested CSV: deterministic across a repeat run,
    both event queues and both execute paths."""
    csv_text = synth_csv()
    node_zones = {n.name: CSV_ZONES[i % len(CSV_ZONES)]
                  for i, n in enumerate(PAPER_NODES)}

    def one(event_queue: str, batch_execute: bool) -> str:
        provider = TraceProvider.from_csv(csv_text, node_zones=node_zones)
        cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
        cluster.profile(250.0)
        engine = CarbonEdgeEngine(cluster, mode="green", provider=provider,
                                  batch_execute=batch_execute)
        drv = AsyncEngineDriver(
            engine, PoissonArrivals(40.0, seed=7),
            lambda uid, hour: TASK, horizon_hours=24.0, max_batch=16,
            batch_window_hours=0.01, tick_hours=1.0,
            event_queue=event_queue)
        return drv.run().to_text()

    ref = one("calendar", True)
    return {
        "zones": len(CSV_ZONES),
        "trace_hours": 24,
        "repeat_match": one("calendar", True) == ref,
        "queue_match": one("heap", True) == ref,
        "exec_path_match": one("calendar", False) == ref,
    }


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def run(smoke: bool = True, out_path: str = "BENCH_sim_scale.json") -> dict:
    _null_driver(64, 0.02, "calendar").run()      # warm numpy dispatch
    _null_driver(64, 0.02, "heap").run()
    replay = [
        bench_replay(20_000),                     # ~2*10^4 events
        bench_replay(120_000),                    # >=10^5 events
    ]
    closed_loop = [
        bench_row(2_000, 0.25),                   # ~3*10^4 events
        bench_row(10_000, 0.4),                   # ~2.5*10^5 events
    ]
    out = {
        "byte_identity": engine_identity(),
        "replay": replay,
        "closed_loop": closed_loop,
        "trace_replay": trace_replay(),
    }
    if not smoke:
        # acceptance scale: a heap-vs-calendar byte-identity replay at
        # 10^7 events, then 10^6 closed-loop clients through the
        # calendar alone (the scalar oracle at this scale is the point
        # of the refactor).
        print("full: 10^7-event replay (heap oracle)...", flush=True)
        out["replay_identity_1e7"] = bench_replay(10_000_000)
        print("full: 10^6 closed-loop clients (calendar)...", flush=True)
        out["full_scale"] = bench_row(1_000_000, 0.2, heap_oracle=False)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    return out


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--full", action="store_true",
                   help="add the 10^6-client / 10^7-event acceptance rows")
    args = p.parse_args()
    out = run(smoke=not args.full)
    print(json.dumps({k: v for k, v in out.items()
                      if k != "byte_identity"} | {
                          "byte_identity": out["byte_identity"]},
                     indent=2))
