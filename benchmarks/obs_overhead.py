"""Observability overhead: the off-path and on-path cost of repro.obs.

Two claims are measured (DESIGN.md §9) and asserted by ``gate_obs``:

- **off**: an engine built without obs and one built with every pillar
  disabled run the same code path — the disabled engine step stays under
  the same loose absolute backstop as the other gates, and a fixed-seed
  sim renders a byte-identical ``metrics.to_text`` report across both
  execute paths whether obs is absent, disabled, or fully enabled;
- **on**: with ALL pillars enabled (decision trace + metrics registry +
  step profiler), the end-to-end ``engine.step`` stays within a bounded
  factor (acceptance: <= 1.25x at N=10^4, B=1024) of the disabled path,
  and never changes a decision.

Sweeps (N, B) through the fleet-scale fixtures, reports per-task times
and the enabled/disabled ratio, and writes ``BENCH_obs.json`` including
the enabled run's per-phase profiler summary. The CI smoke runs
``run(smoke=True)`` (which still includes the acceptance row); gate
assertions live in ``benchmarks/ci_gates.py``
(``python -m benchmarks.ci_gates obs``).
"""
from __future__ import annotations

import json
import time
from typing import Dict

from benchmarks.fleet_scale import make_fleet, make_tasks

# (n_nodes, batch) rows; the (10_000, 1024) acceptance row runs in both
# sweeps — the 1.25x bound is defined there.
FULL_ROWS = ((1_000, 256), (10_000, 1024), (100_000, 1024))
SMOKE_ROWS = ((512, 64), (2_048, 256), (10_000, 1024))


def bench_row(n: int, b: int, *, reps: int, seed: int = 0) -> Dict:
    """Best-of-reps e2e ``engine.step``: obs fully enabled vs disabled.
    The two engines step in alternation so both paths see the same
    machine state (CPU frequency, caches) — the ratio is what the gate
    bounds, and block-sequenced timing would let drift between blocks
    masquerade as overhead."""
    from repro.core.api import CarbonEdgeEngine
    from repro.obs import Observability

    obs = Observability.all()
    eng_off = CarbonEdgeEngine(make_fleet(n, seed=seed))
    eng_on = CarbonEdgeEngine(make_fleet(n, seed=seed), obs=obs)
    tasks = make_tasks(b, seed=seed)
    eng_off.submit_many(tasks)
    off_nodes = [r.node for r in eng_off.step()]   # warm (caches, memo)
    eng_on.submit_many(tasks)
    on_nodes = [r.node for r in eng_on.step()]
    assert on_nodes == off_nodes, \
        "enabled observability changed a scheduling decision"
    offs = []
    ons = []
    for _ in range(reps):
        eng_off.submit_many(tasks)
        t0 = time.perf_counter()
        eng_off.step()
        offs.append(time.perf_counter() - t0)
        eng_on.submit_many(tasks)
        t0 = time.perf_counter()
        eng_on.step()
        ons.append(time.perf_counter() - t0)
    off_s, on_s = min(offs), min(ons)
    # the gated estimator: median of per-adjacent-pair ratios — each pair
    # ran back-to-back under the same machine state, and the median drops
    # the scheduler-noise outliers that a ratio-of-minima can still catch
    pair = sorted(on / off for on, off in zip(ons, offs))
    overhead_x = pair[len(pair) // 2]
    steps = reps + 1
    assert obs.trace.count == steps * b, (obs.trace.count, steps, b)
    for phase in ("select", "execute", "bill", "observe"):
        assert obs.profiler.count(phase) == steps, (phase, steps)
    return {
        "n_nodes": n, "batch": b, "steps": steps,
        "disabled_step_ms": off_s * 1e3,
        "enabled_step_ms": on_s * 1e3,
        "disabled_per_task_ms": off_s * 1e3 / b,
        "enabled_per_task_ms": on_s * 1e3 / b,
        "overhead_x": overhead_x,
        "overhead_best_x": on_s / off_s,
        "trace_rows": obs.trace.count,
        "profiler": obs.profiler.summary(),
    }


def sim_byte_identity() -> Dict:
    """Fixed-seed sim ``to_text`` byte-equality: obs absent vs disabled vs
    fully enabled, across the batched and scalar-oracle execute paths."""
    from repro.core.api import (CarbonEdgeEngine, ForecastProvider,
                                StaticProvider, TraceProvider)
    from repro.core.cluster import EdgeCluster, PAPER_NODES
    from repro.core.scheduler import Task
    from repro.core.temporal import DeferrableTask, synthetic_trace
    from repro.obs import Observability
    from repro.sim import AsyncEngineDriver, PoissonArrivals

    def one(obs, batch_execute):
        c = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
        c.profile(250.0)
        provider = TraceProvider(
            {"node-high": synthetic_trace("coal-heavy", 620.0,
                                          solar_dip=0.1),
             "node-medium": synthetic_trace("cn-average", 530.0,
                                            solar_dip=0.3),
             "node-green": synthetic_trace("hydro-rich", 380.0,
                                           solar_dip=0.5)},
            fallback=StaticProvider.from_cluster(c))
        eng = CarbonEdgeEngine(c, mode="green", provider=provider,
                               batch_execute=batch_execute, obs=obs)

        def factory(uid, hour):
            if uid % 3 == 0:
                return DeferrableTask(cpu=0.05, mem_mb=16.0,
                                      base_latency_ms=250.0,
                                      deadline_hours=4.0)
            return Task(cpu=0.05, mem_mb=16.0, base_latency_ms=250.0)

        d = AsyncEngineDriver(eng,
                              PoissonArrivals(rate_per_hour=240.0, seed=11),
                              factory, horizon_hours=1.0, max_batch=16,
                              forecast=ForecastProvider(provider),
                              tick_hours=0.25, slo_latency_s=2.0, obs=obs)
        return d.run().to_text()

    out = {}
    for batch_execute in (True, False):
        key = "batched" if batch_execute else "scalar"
        golden = one(None, batch_execute)
        out[f"{key}_disabled_match"] = \
            one(Observability(), batch_execute) == golden
        out[f"{key}_enabled_match"] = \
            one(Observability.all(), batch_execute) == golden
    return out


def run(smoke: bool = False, out_path: str = "BENCH_obs.json") -> Dict:
    rows = []
    for n, b in (SMOKE_ROWS if smoke else FULL_ROWS):
        # the acceptance row gets the most pairs — the median estimator
        # tightens with sample count and each pair costs ~2.5 ms there
        reps = 20 if n <= 2_048 else (40 if n <= 10_000 else 5)
        row = bench_row(n, b, reps=reps)
        rows.append(row)
        print(f"obs e2e N={n:>7} B={b:>5}: off {row['disabled_step_ms']:7.3f}"
              f" ms  on {row['enabled_step_ms']:7.3f} ms"
              f"  ({row['overhead_x']:5.2f}x,"
              f" {row['enabled_per_task_ms']*1e3:7.2f} us/task on)")
    identity = sim_byte_identity()
    print("sim byte-identity:", identity)
    out = {"rows": rows, "byte_identity": identity, "smoke": smoke,
           "overhead_bound_x": 1.25}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    return out


def main(smoke: bool = False):
    return run(smoke=smoke)


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
