"""Observability overhead: the off-path and on-path cost of repro.obs.

Three claims are measured (DESIGN.md §9, §12) and asserted by
``gate_obs``:

- **off**: an engine built without obs and one built with every pillar
  disabled run the same code path — the disabled engine step stays under
  the same loose absolute backstop as the other gates, and a fixed-seed
  sim renders a byte-identical ``metrics.to_text`` report whether obs is
  absent, disabled, or fully enabled, across both execute paths AND both
  event queues (``byte_identity``);
- **on**: with ALL pillars enabled (decision trace + metrics registry +
  step profiler + journeys + rollups + alerts), the end-to-end
  ``engine.step`` stays within a bounded factor (acceptance: <= 1.3x at
  N=10^4, B=1024) of the disabled path, and never changes a decision.
  Small shapes get an explicit looser bound — see
  ``SMALL_SHAPE_RATIONALE``;
- **journeys/rollups/alerts determinism**: a fixed-seed chaos scenario
  (tenancy + resilience + scripted faults + closed-loop clients, obs
  wired to BOTH the engine and the driver) renders byte-identical
  ``journeys.to_text`` / ``rollups.to_text`` / ``alerts.to_text`` across
  a repeat run and across the calendar/heap event queues, with at least
  one alert actually firing (``journey_determinism``). A 10^5-client
  closed-loop run must export rollups with memory O(windows) — bounded
  by the allocated window capacity, independent of task count
  (``rollup_scale``).

Sweeps (N, B) through the fleet-scale fixtures, reports per-task times
and the enabled/disabled ratio, and writes ``BENCH_obs.json`` including
the enabled run's per-phase profiler summary. The CI smoke runs
``run(smoke=True)`` (which still includes the acceptance row and the
10^5-client scale row); gate assertions live in ``benchmarks/ci_gates.py``
(``python -m benchmarks.ci_gates obs``).
"""
from __future__ import annotations

import json
import time
from typing import Dict

from benchmarks.fleet_scale import make_fleet, make_tasks

# (n_nodes, batch) rows; the (10_000, 1024) acceptance row runs in both
# sweeps — the 1.3x bound is defined there.
FULL_ROWS = ((1_000, 256), (10_000, 1024), (100_000, 1024))
SMOKE_ROWS = ((512, 64), (2_048, 256), (10_000, 1024))

# The acceptance bound, defined at N=10^4 B=1024 where per-task work
# dominates. 1.25 (trace+metrics+profiler, PR 7) + rollup folds (PR 10).
OVERHEAD_BOUND_X = 1.3

# Small shapes (N=512, B=64) amortize the fixed per-step obs cost —
# snapshot assembly, registry scatter set-up, profiler clock reads, one
# rollup fold — over few tasks, so the *ratio* runs hot (~1.8x measured)
# while the absolute cost stays microscopic (the disabled step is
# ~100 us there). The explicit small-shape bound documents that this is
# a fixed-cost artifact, not a scaling problem: the per-task acceptance
# bound above is the claim that matters at fleet scale.
SMALL_SHAPE_BOUND_X = 2.5
SMALL_SHAPE_RATIONALE = (
    "fixed per-step obs cost (snapshot + registry scatter set-up + "
    "profiler clocks + one rollup fold) amortized over <=64 tasks; "
    "absolute overhead is microseconds while the ratio runs ~1.8x")


def bench_row(n: int, b: int, *, reps: int, seed: int = 0) -> Dict:
    """Best-of-reps e2e ``engine.step``: obs fully enabled vs disabled.
    The two engines step in alternation so both paths see the same
    machine state (CPU frequency, caches) — the ratio is what the gate
    bounds, and block-sequenced timing would let drift between blocks
    masquerade as overhead."""
    from repro.core.api import CarbonEdgeEngine
    from repro.obs import Observability

    obs = Observability.all()
    eng_off = CarbonEdgeEngine(make_fleet(n, seed=seed))
    eng_on = CarbonEdgeEngine(make_fleet(n, seed=seed), obs=obs)
    tasks = make_tasks(b, seed=seed)
    eng_off.submit_many(tasks)
    off_nodes = [r.node for r in eng_off.step()]   # warm (caches, memo)
    eng_on.submit_many(tasks)
    on_nodes = [r.node for r in eng_on.step()]
    assert on_nodes == off_nodes, \
        "enabled observability changed a scheduling decision"
    offs = []
    ons = []
    for _ in range(reps):
        eng_off.submit_many(tasks)
        t0 = time.perf_counter()
        eng_off.step()
        offs.append(time.perf_counter() - t0)
        eng_on.submit_many(tasks)
        t0 = time.perf_counter()
        eng_on.step()
        ons.append(time.perf_counter() - t0)
    off_s, on_s = min(offs), min(ons)
    # the gated estimator: median of per-adjacent-pair ratios — each pair
    # ran back-to-back under the same machine state, and the median drops
    # the scheduler-noise outliers that a ratio-of-minima can still catch
    pair = sorted(on / off for on, off in zip(ons, offs))
    overhead_x = pair[len(pair) // 2]
    steps = reps + 1
    assert obs.trace.count == steps * b, (obs.trace.count, steps, b)
    for phase in ("select", "execute", "bill", "observe"):
        assert obs.profiler.count(phase) == steps, (phase, steps)
    # the engine folds every successful step into the rollup store too
    assert obs.rollups.n_windows >= 1
    assert int(obs.rollups.tasks[:1].sum()) == steps * b
    return {
        "n_nodes": n, "batch": b, "steps": steps,
        "disabled_step_ms": off_s * 1e3,
        "enabled_step_ms": on_s * 1e3,
        "disabled_per_task_ms": off_s * 1e3 / b,
        "enabled_per_task_ms": on_s * 1e3 / b,
        "overhead_x": overhead_x,
        "overhead_best_x": on_s / off_s,
        "trace_rows": obs.trace.count,
        "profiler": obs.profiler.summary(),
    }


def sim_byte_identity() -> Dict:
    """Fixed-seed sim ``to_text`` byte-equality: obs absent vs disabled vs
    fully enabled, across the batched/scalar execute paths AND the
    calendar/heap event queues."""
    from repro.core.api import (CarbonEdgeEngine, ForecastProvider,
                                StaticProvider, TraceProvider)
    from repro.core.cluster import EdgeCluster, PAPER_NODES
    from repro.core.scheduler import Task
    from repro.core.temporal import DeferrableTask, synthetic_trace
    from repro.obs import Observability
    from repro.sim import AsyncEngineDriver, PoissonArrivals

    def one(obs, batch_execute, event_queue):
        c = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
        c.profile(250.0)
        provider = TraceProvider(
            {"node-high": synthetic_trace("coal-heavy", 620.0,
                                          solar_dip=0.1),
             "node-medium": synthetic_trace("cn-average", 530.0,
                                            solar_dip=0.3),
             "node-green": synthetic_trace("hydro-rich", 380.0,
                                           solar_dip=0.5)},
            fallback=StaticProvider.from_cluster(c))
        eng = CarbonEdgeEngine(c, mode="green", provider=provider,
                               batch_execute=batch_execute, obs=obs)

        def factory(uid, hour):
            if uid % 3 == 0:
                return DeferrableTask(cpu=0.05, mem_mb=16.0,
                                      base_latency_ms=250.0,
                                      deadline_hours=4.0)
            return Task(cpu=0.05, mem_mb=16.0, base_latency_ms=250.0)

        d = AsyncEngineDriver(eng,
                              PoissonArrivals(rate_per_hour=240.0, seed=11),
                              factory, horizon_hours=1.0, max_batch=16,
                              forecast=ForecastProvider(provider),
                              tick_hours=0.25, slo_latency_s=2.0, obs=obs,
                              event_queue=event_queue)
        return d.run().to_text()

    out = {}
    for batch_execute in (True, False):
        path = "batched" if batch_execute else "scalar"
        for queue in ("calendar", "heap"):
            golden = one(None, batch_execute, queue)
            out[f"{path}_{queue}_disabled_match"] = \
                one(Observability(), batch_execute, queue) == golden
            out[f"{path}_{queue}_enabled_match"] = \
                one(Observability.all(), batch_execute, queue) == golden
    return out


def _chaos_driver(obs, event_queue: str):
    """The fixed-seed chaos scenario (examples/chaos_serving.py):
    two closed-loop tenants through a lagged-detection node crash + feed
    blackout, obs wired to BOTH the engine and the driver."""
    from repro.core.api import CarbonEdgeEngine, StaticProvider
    from repro.core.cluster import EdgeCluster, PAPER_NODES
    from repro.resilience import (Fault, FaultInjector, Resilience,
                                  ResilientProvider)
    from repro.sim import (AsyncEngineDriver, ClientPopulation,
                           ClosedLoopClientPool)
    from repro.tenancy import TenantPolicy, TenantRegistry, TenantSpec
    from repro.tenancy.spec import TenantTask

    faults = [Fault(0.004, "crash", "node-green", detected=False),
              Fault(0.008, "detect", "node-green"),
              Fault(0.010, "blackout"),
              Fault(0.016, "restore"),
              Fault(0.020, "recover", "node-green")]
    cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    cluster.profile(250.0)
    provider = ResilientProvider(StaticProvider(
        {n: cluster.nodes[n].spec.carbon_intensity for n in cluster.nodes}))
    registry = TenantRegistry([
        TenantSpec("gold", mode="green", priority=2, allowance_g=0.05,
                   period_hours=0.25),
        TenantSpec("batch", mode="green")])
    engine = CarbonEdgeEngine(
        cluster, mode="green", policy=TenantPolicy(registry=registry),
        provider=provider,
        resilience=Resilience(max_attempts=3, backoff_base_hours=0.002),
        obs=obs)
    pool = ClosedLoopClientPool(
        [ClientPopulation("gold", 6, mean_think_hours=0.0008,
                          slo_latency_s=2.0, priority=2),
         ClientPopulation("batch", 4, mean_think_hours=0.002,
                          slo_latency_s=10.0)],
        seed=4)
    return AsyncEngineDriver(
        engine, None,
        lambda uid, hour, tenant: TenantTask(cpu=0.05, mem_mb=16.0,
                                             base_latency_ms=250.0,
                                             tenant=tenant),
        horizon_hours=0.03, max_batch=8, slo_latency_s=5.0, clients=pool,
        faults=FaultInjector.scripted(faults), obs=obs,
        event_queue=event_queue)


def journey_determinism():
    """Byte-determinism of the three new pillars on the chaos scenario:
    journeys/rollups/alerts ``to_text`` identical across a repeat run
    and across the calendar/heap event queues, with the enabled run's
    ``metrics.to_text`` still byte-identical to the obs-absent golden
    on both queues, and at least one alert firing (a vacuously empty
    alert stream would make the determinism claim meaningless)."""
    from repro.obs import Observability
    from repro.obs.alerts import default_rules

    def enabled():
        return Observability.all(
            rollup_window_hours=0.005,
            alert_rules=default_rules(availability_floor=0.9, min_tasks=4))

    def run(obs, queue):
        d = _chaos_driver(obs, queue)
        return d.run().to_text()

    texts = {}
    stats = {}
    for label, queue in (("cal_a", "calendar"), ("cal_b", "calendar"),
                         ("heap", "heap")):
        obs = enabled()
        metrics_text = run(obs, queue)
        texts[label] = {"journeys": obs.journeys.to_text(),
                        "rollups": obs.rollups.to_text(),
                        "alerts": obs.alerts.to_text(),
                        "metrics": metrics_text}
        if label == "cal_a":
            cp = obs.journeys.critical_path()
            stats = {"journeys": obs.journeys.max_uid,
                     "states": obs.journeys.state_counts(),
                     "windows": obs.rollups.n_windows,
                     "alert_events": len(obs.alerts.events),
                     "phase_identity_max_abs_err_h":
                         cp["identity_max_abs_err_h"]}
    golden_cal = run(None, "calendar")
    golden_heap = run(None, "heap")
    out = {}
    for surface in ("journeys", "rollups", "alerts"):
        out[f"{surface}_repeat_match"] = \
            texts["cal_a"][surface] == texts["cal_b"][surface]
        out[f"{surface}_queue_match"] = \
            texts["cal_a"][surface] == texts["heap"][surface]
    out["chaos_metrics_calendar_match"] = \
        texts["cal_a"]["metrics"] == golden_cal
    out["chaos_metrics_heap_match"] = texts["heap"]["metrics"] == golden_heap
    out["alerts_fired"] = stats["alert_events"] > 0
    out["phase_identity_ok"] = \
        stats["phase_identity_max_abs_err_h"] < 1e-9
    return out, stats


def rollup_scale_row(n_clients: int = 100_000,
                     horizon_hours: float = 0.03) -> Dict:
    """A 10^5-client closed-loop run (the PR 9 null-executor scenario, so
    the row times obs folding rather than engine scoring) with rollups +
    alerts enabled: the rollup store must export with memory O(windows) —
    bounded by the allocated window capacity and tenant count, independent
    of how many tasks streamed through it."""
    from repro.obs import Observability
    from benchmarks.sim_scale import _null_driver

    obs = Observability(trace=False, metrics=False, profile=False,
                        journeys=False, rollups=True, alerts=True,
                        rollup_window_hours=0.002)
    drv = _null_driver(n_clients, horizon_hours, "calendar")
    drv.obs = obs
    t0 = time.perf_counter()
    m = drv.run()
    wall = time.perf_counter() - t0
    roll = obs.rollups
    exported = roll.export()
    assert len(exported["tasks"]) == roll.n_windows
    # O(windows) memory: 5 f8/i8 scalar columns + the (5,) verdict row +
    # one f8 per tenant per window — a loose 256 B/window bound with a
    # page of slack, nothing proportional to task count
    cap_bytes = 256 * roll.capacity + 4096
    return {
        "n_clients": n_clients,
        "horizon_hours": horizon_hours,
        "events": drv.events_processed,
        "tasks": m.n_records,
        "windows": roll.n_windows,
        "rollup_nbytes": roll.nbytes,
        "memory_ok": bool(roll.nbytes <= cap_bytes),
        "wall_s": round(wall, 4),
        "rollup_on_per_event_us": round(
            wall / max(1, drv.events_processed) * 1e6, 4),
    }


def run(smoke: bool = False, out_path: str = "BENCH_obs.json") -> Dict:
    rows = []
    for n, b in (SMOKE_ROWS if smoke else FULL_ROWS):
        # the acceptance row gets the most pairs — the median estimator
        # tightens with sample count and each pair costs ~2.5 ms there
        reps = 20 if n <= 2_048 else (40 if n <= 10_000 else 5)
        row = bench_row(n, b, reps=reps)
        rows.append(row)
        print(f"obs e2e N={n:>7} B={b:>5}: off {row['disabled_step_ms']:7.3f}"
              f" ms  on {row['enabled_step_ms']:7.3f} ms"
              f"  ({row['overhead_x']:5.2f}x,"
              f" {row['enabled_per_task_ms']*1e3:7.2f} us/task on)")
    identity = sim_byte_identity()
    print("sim byte-identity:", identity)
    journeys, journey_stats = journey_determinism()
    print("journey determinism:", journeys)
    print("journey stats:", journey_stats)
    scale = rollup_scale_row(n_clients=100_000)
    print(f"rollup scale: {scale['tasks']} tasks over {scale['windows']} "
          f"windows in {scale['rollup_nbytes']} B "
          f"(memory_ok={scale['memory_ok']})")
    out = {"rows": rows, "byte_identity": identity,
           "journey_determinism": journeys,
           "journey_stats": journey_stats,
           "rollup_scale": scale, "smoke": smoke,
           "overhead_bound_x": OVERHEAD_BOUND_X,
           "small_shape_bound_x": SMALL_SHAPE_BOUND_X,
           "small_shape_rationale": SMALL_SHAPE_RATIONALE}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    return out


def main(smoke: bool = False):
    return run(smoke=smoke)


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
