"""Train a small LM for a few hundred steps with carbon accounting.

Uses a ~4M-param qwen3-family config on synthetic Markov data; loss should
drop well below the uniform baseline ln(vocab). Demonstrates the training
substrate (AdamW, chunked CE, remat, data pipeline, checkpointing) that the
dry-run lowers at production scale.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse
import math
import sys

from repro.launch import train as train_launcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    loss = train_launcher.main([
        "--arch", "qwen3-1.7b", "--steps", str(args.steps),
        "--batch", "8", "--seq", "128", "--lr", "1e-3",
        "--checkpoint", "/tmp/repro_quickstart.msgpack",
    ])
    baseline = math.log(512)
    print(f"final loss {loss:.3f} vs uniform baseline ln(512)={baseline:.3f}")
    if loss > baseline - 0.5:
        print("WARNING: loss barely moved; increase --steps")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
