"""Observability walkthrough: why did task X land on node Y at cut Z?

Runs a partition-aware engine with every repro.obs pillar enabled,
then answers the operator questions the subsystem exists for (DESIGN.md
§9, §12): per-task decision forensics from the trace ring (winning
score vs runner-up, forecast interval, carbon billed), Prometheus-style
metrics exposition, per-phase step timing, a deterministic JSONL
export — then a closed-loop chaos drill to walk one request's full
causal journey (arrival -> parks -> failover -> execute), the windowed
rollup series, and the alert fire/resolve log.

Run:  PYTHONPATH=src python examples/observability_demo.py
"""
import numpy as np

from repro.core.api import CarbonEdgeEngine, StaticProvider
from repro.core.cluster import PAPER_NODES, EdgeCluster
from repro.core.scheduler import Task
from repro.obs import Observability
from repro.partition.policy import PartitionPolicy
from repro.partition.profile import profile_costs

# -- a cluster, a partition-aware policy, and obs fully on ------------------
cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
cluster.profile(250.0)

# 4-layer toy model: equal compute, one cheap boundary after layer 2
profile = profile_costs([12.0, 12.0, 12.0, 12.0],
                        boundary_bytes=[4e5, 1e3, 4e5, 0.0])
policy = PartitionPolicy(profile)

obs = Observability.all()          # trace + metrics + profiler
eng = CarbonEdgeEngine(cluster, mode="green", policy=policy, obs=obs)

rng = np.random.default_rng(7)
for step in range(4):
    eng.submit_many([Task(cpu=float(c), mem_mb=32.0, base_latency_ms=250.0)
                     for c in rng.choice([0.05, 0.2, 0.6], size=32)])
    eng.step()

# -- 1. decision forensics: why did task i go where it went? ----------------
trace = obs.trace
print(f"=== trace: {trace.count} decisions recorded ===")
row = trace.row(len(trace) - 1)          # most recent decision
print(trace.explain(row["step"], row["task"]))
if row["score"] is not None and row["runner_up"] is not None:
    margin = row["score"] - row["runner_up"]
    print(f"won by a margin of {margin:.4f} score units over the "
          f"runner-up\n")

# -- 2. aggregates straight off the columns ---------------------------------
print("verdicts:", trace.verdict_counts())
print("cut histogram (cut index -> tasks):", trace.cut_histogram())

# -- 3. metrics: Prometheus exposition --------------------------------------
print("\n=== metrics (exposition excerpt) ===")
text = obs.metrics.to_text()
for line in text.splitlines():
    if line.startswith(("engine_tasks_total", "engine_carbon_g_total",
                        "engine_outcomes_total")):
        print(line)

# -- 4. profiler: where did the step time go? -------------------------------
print("\n=== per-phase step timing ===")
for phase, s in sorted(obs.profiler.summary()["phases"].items()):
    print(f"{phase:10s} n={s['count']:3d}  total={s['total_s']*1e3:7.3f} ms"
          f"  p50={s['p50_s']*1e6:7.1f} us  p95={s['p95_s']*1e6:7.1f} us")

# -- 5. deep report + deterministic export ----------------------------------
rep = eng.report(deep=True)
print("\noutcome totals:", rep["outcomes"],
      " deferred depth:", rep["deferred_depth"])
path = "/tmp/obs_trace.jsonl"
n = trace.export_jsonl(path)
print(f"exported {n} trace rows to {path} (deterministic for a fixed seed)")

# -- 6. journeys: one request's whole causal path (DESIGN.md §12) -----------
# A closed-loop chaos drill (node crash with lagged detection, then
# recovery) with the obs hub wired to BOTH the engine and the driver:
# journeys record the per-request life, rollups fold the run into
# fixed-width windows, alerts turn the windows into fire/resolve events.
from repro.obs import default_rules
from repro.resilience import (Fault, FaultInjector, Resilience,
                              ResilientProvider)
from repro.sim import (AsyncEngineDriver, ClientPopulation,
                       ClosedLoopClientPool)
from repro.tenancy import TenantPolicy, TenantRegistry, TenantSpec
from repro.tenancy.spec import TenantTask

cluster2 = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
cluster2.profile(250.0)
provider = ResilientProvider(StaticProvider(
    {n: cluster2.nodes[n].spec.carbon_intensity for n in cluster2.nodes}))
obs2 = Observability.all(
    rollup_window_hours=0.005,
    alert_rules=default_rules(availability_floor=0.9, min_tasks=4))
eng2 = CarbonEdgeEngine(
    cluster2, mode="green",
    policy=TenantPolicy(registry=TenantRegistry(
        [TenantSpec("gold", mode="green", priority=2),
         TenantSpec("batch", mode="green")])),
    provider=provider,
    resilience=Resilience(max_attempts=3, backoff_base_hours=0.002),
    obs=obs2)
pool = ClosedLoopClientPool(
    [ClientPopulation("gold", 6, mean_think_hours=0.0008,
                      slo_latency_s=2.0, priority=2),
     ClientPopulation("batch", 4, mean_think_hours=0.002,
                      slo_latency_s=10.0)],
    seed=4)
driver = AsyncEngineDriver(
    eng2, None,
    lambda uid, hour, tenant: TenantTask(cpu=0.05, mem_mb=16.0,
                                         base_latency_ms=250.0,
                                         tenant=tenant),
    horizon_hours=0.03, max_batch=8, slo_latency_s=5.0, clients=pool,
    faults=FaultInjector.scripted([
        Fault(0.004, "crash", "node-green", detected=False),
        Fault(0.008, "detect", "node-green"),
        Fault(0.020, "recover", "node-green")]),
    obs=obs2)
driver.run()

jt = obs2.journeys
print(f"\n=== journeys: {jt.max_uid} requests, "
      f"states {jt.state_counts()} ===")
# explain the most-drained completed request — the one with the most
# eventful causal path through the drill
busiest = max((u for u in range(1, jt.max_uid + 1)
               if jt.state[u] == 1), key=lambda u: int(jt.drains[u]))
print(jt.explain_journey(busiest))

cp = jt.critical_path()
print(f"\ncritical path over {cp['journeys']} completed journeys "
      f"(phase shares of e2e):")
for phase in ("plan_defer", "queue_wait", "budget_defer",
              "retry_backoff", "service"):
    print(f"  {phase:14s} {cp[f'{phase}_share']:6.1%}")
print(f"  phase-sum identity residual: "
      f"{cp['identity_max_abs_err_h']:.3g} h")

# -- 7. rollups: the run as O(windows) series -------------------------------
roll = obs2.rollups
print(f"\n=== rollups: {roll.n_windows} windows of "
      f"{roll.window_hours * 60:.1f} min (store is {roll.nbytes} B) ===")
for line in roll.to_text().splitlines()[:4]:
    print(" ", line)

# -- 8. alerts: windows -> deterministic fire/resolve events ----------------
print("\n=== alert events ===")
for ev in obs2.alerts.events:
    print(" ", ev.render())
print("active at end of run:", obs2.alerts.active or "none")
