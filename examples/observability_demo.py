"""Observability walkthrough: why did task X land on node Y at cut Z?

Runs a partition-aware engine with every repro.obs pillar enabled,
then answers the operator questions the subsystem exists for (DESIGN.md
§9): per-task decision forensics from the trace ring (winning score vs
runner-up, forecast interval, carbon billed), Prometheus-style metrics
exposition, per-phase step timing, and a deterministic JSONL export.

Run:  PYTHONPATH=src python examples/observability_demo.py
"""
import numpy as np

from repro.core.api import CarbonEdgeEngine, StaticProvider
from repro.core.cluster import PAPER_NODES, EdgeCluster
from repro.core.scheduler import Task
from repro.obs import Observability
from repro.partition.policy import PartitionPolicy
from repro.partition.profile import profile_costs

# -- a cluster, a partition-aware policy, and obs fully on ------------------
cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
cluster.profile(250.0)

# 4-layer toy model: equal compute, one cheap boundary after layer 2
profile = profile_costs([12.0, 12.0, 12.0, 12.0],
                        boundary_bytes=[4e5, 1e3, 4e5, 0.0])
policy = PartitionPolicy(profile)

obs = Observability.all()          # trace + metrics + profiler
eng = CarbonEdgeEngine(cluster, mode="green", policy=policy, obs=obs)

rng = np.random.default_rng(7)
for step in range(4):
    eng.submit_many([Task(cpu=float(c), mem_mb=32.0, base_latency_ms=250.0)
                     for c in rng.choice([0.05, 0.2, 0.6], size=32)])
    eng.step()

# -- 1. decision forensics: why did task i go where it went? ----------------
trace = obs.trace
print(f"=== trace: {trace.count} decisions recorded ===")
row = trace.row(len(trace) - 1)          # most recent decision
print(trace.explain(row["step"], row["task"]))
if row["score"] is not None and row["runner_up"] is not None:
    margin = row["score"] - row["runner_up"]
    print(f"won by a margin of {margin:.4f} score units over the "
          f"runner-up\n")

# -- 2. aggregates straight off the columns ---------------------------------
print("verdicts:", trace.verdict_counts())
print("cut histogram (cut index -> tasks):", trace.cut_histogram())

# -- 3. metrics: Prometheus exposition --------------------------------------
print("\n=== metrics (exposition excerpt) ===")
text = obs.metrics.to_text()
for line in text.splitlines():
    if line.startswith(("engine_tasks_total", "engine_carbon_g_total",
                        "engine_outcomes_total")):
        print(line)

# -- 4. profiler: where did the step time go? -------------------------------
print("\n=== per-phase step timing ===")
for phase, s in sorted(obs.profiler.summary()["phases"].items()):
    print(f"{phase:10s} n={s['count']:3d}  total={s['total_s']*1e3:7.3f} ms"
          f"  p50={s['p50_s']*1e6:7.1f} us  p95={s['p95_s']*1e6:7.1f} us")

# -- 5. deep report + deterministic export ----------------------------------
rep = eng.report(deep=True)
print("\noutcome totals:", rep["outcomes"],
      " deferred depth:", rep["deferred_depth"])
path = "/tmp/obs_trace.jsonl"
n = trace.export_jsonl(path)
print(f"exported {n} trace rows to {path} (deterministic for a fixed seed)")
