"""Beyond-paper demo: multi-tenant carbon budgets + temporal shifting.

Two tenants share the paper's 3-node edge cluster through the
``repro.tenancy`` subsystem (DESIGN.md §7). Tenant A has a tight periodic
carbon allowance: as it drains, the TenantPolicy escalates A's effective
mode (performance -> balanced -> green), clamps its placements to the
greenest feasible node, and finally defers A's work to its next
accounting period — all applied by the engine before selection. Tenant B
is unaffected. Deferrable batch jobs submitted in the evening still shift
into the midday solar dip via the TemporalScheduler.

(The pre-tenancy BudgetedRouter API survives as a deprecation shim over
this policy — see repro/core/budget.py.)

Run:  PYTHONPATH=src python examples/carbon_budgeted_serving.py
"""
from repro.core.api import CarbonEdgeEngine
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.scheduler import MODES
from repro.core.temporal import (DeferrableTask, TemporalScheduler,
                                 synthetic_trace)
from repro.tenancy import (TenantPolicy, TenantRegistry, TenantSpec,
                           TenantTask)

# -- multi-tenant budgets -----------------------------------------------------
cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
cluster.profile(250.0)

registry = TenantRegistry([
    TenantSpec("tenant-a", allowance_g=0.03, period_hours=1.0),   # tight
    TenantSpec("tenant-b", allowance_g=5.0, period_hours=1.0),    # generous
])
policy = TenantPolicy(registry=registry)
engine = CarbonEdgeEngine(cluster, mode="performance", policy=policy)

print("tenant-a requests as its budget drains (one engine step each):")
for i in range(10):
    engine.submit(TenantTask(cpu=0.05, mem_mb=16.0, base_latency_ms=250.0,
                             tenant="tenant-a"))
    mode = policy.effective_modes()["tenant-a"]
    results = engine.step(now_hour=0.0)
    kind, val = engine.last_outcomes[0]
    b = registry.report()["tenant-a"]
    node = results[0].node if results else "-"
    print(f"  req {i:2d}: mode={mode:12s} node={node:11s} outcome={kind:6s} "
          f"spent={b['spent_g']:.4f}/{b['allowance_g']:.2f} g")
    if kind == "defer":
        print(f"          -> parked until hour {val:g} "
              "(tenant-a's next accounting period)")
        break

engine.submit(TenantTask(cpu=0.05, mem_mb=16.0, tenant="tenant-b"))
engine.step(now_hour=0.0)
kind, _ = engine.last_outcomes[0]
print(f"tenant-b unaffected: outcome={kind}, "
      f"spent={registry.report()['tenant-b']['spent_g']:.4f} g")

# deferred work resumes automatically once the period rolls over
rep = engine.run_until(2.0, start_hour=0.0)
a = rep["tenants"]["tenant-a"]
print(f"after run_until(2.0): tenant-a completed={a['completed']} "
      f"deferred={a['deferred']} (fresh period budget: "
      f"{a['spent_g']:.4f}/{a['allowance_g']:.2f} g)\n")

# -- temporal shifting --------------------------------------------------------
cluster2 = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
cluster2.profile(250.0)
traces = {
    "node-high": synthetic_trace("coal-heavy", 620.0, solar_dip=0.1),
    "node-medium": synthetic_trace("cn-average", 530.0, solar_dip=0.3),
    "node-green": synthetic_trace("hydro-rich", 380.0, solar_dip=0.5),
}
sched = TemporalScheduler(cluster2, traces, MODES["green"])
print("evening batch job (19:00) with increasing deadline slack:")
for deadline in (0.0, 4.0, 16.0):
    t = DeferrableTask(cpu=0.05, mem_mb=16, deadline_hours=deadline,
                       duration_hours=0.5)
    pl = sched.select(t, now_hour=19.0)
    print(f"  deadline {deadline:4.1f}h -> start {pl.start_hour % 24:5.1f}h on "
          f"{pl.node}, expected {pl.expected_carbon_g:.3f} g "
          f"(deferred {pl.deferred_hours:.1f}h)")
