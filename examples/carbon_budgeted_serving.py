"""Beyond-paper demo: multi-tenant carbon budgets + temporal shifting.

Two tenants share a 3-region pod fleet. Tenant A has a tight carbon
allowance: as it drains, the BudgetedRouter escalates it from performance
mode to green mode and finally denies admission; tenant B is unaffected.
Deferrable batch jobs submitted in the evening shift into the midday solar
dip via the TemporalScheduler.

Run:  PYTHONPATH=src python examples/carbon_budgeted_serving.py
"""
from repro.core.budget import BudgetedRouter
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.energy import RooflineTerms
from repro.core.router import GreenRouter, PodSpec
from repro.core.scheduler import MODES
from repro.core.temporal import (DeferrableTask, TemporalScheduler,
                                 synthetic_trace)

PODS = [
    PodSpec("pod-high", 256, "coal-heavy", 620.0),
    PodSpec("pod-medium", 256, "cn-average", 530.0),
    PodSpec("pod-green", 256, "hydro-rich", 380.0),
]
TERMS = RooflineTerms(0.010, 0.004, 0.002)   # a 10 ms inference step

# -- multi-tenant budgets -----------------------------------------------------
router = GreenRouter(PODS, mode="performance")
router.seed_profile({p.name: TERMS for p in PODS})
br = BudgetedRouter(router)
br.register_tenant("tenant-a", allowance_g=1.0)     # tight budget
br.register_tenant("tenant-b", allowance_g=50.0)    # generous

print("tenant-a requests as its budget drains:")
for i in range(12):
    res = br.admit("tenant-a", TERMS)
    if res.admitted:
        br.commit("tenant-a", res.pod, TERMS)
    if i % 3 == 0 or not res.admitted:
        b = br.tenants["tenant-a"]
        print(f"  req {i:2d}: mode={res.mode:12s} pod={res.pod} "
              f"admitted={res.admitted} spent={b.spent_g:.3f}/{b.allowance_g:.1f} g")
    if not res.admitted:
        break

res_b = br.admit("tenant-b", TERMS)
print(f"tenant-b unaffected: mode={res_b.mode}, admitted={res_b.admitted}\n")

# -- temporal shifting --------------------------------------------------------
cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
cluster.profile(250.0)
traces = {
    "node-high": synthetic_trace("coal-heavy", 620.0, solar_dip=0.1),
    "node-medium": synthetic_trace("cn-average", 530.0, solar_dip=0.3),
    "node-green": synthetic_trace("hydro-rich", 380.0, solar_dip=0.5),
}
sched = TemporalScheduler(cluster, traces, MODES["green"])
print("evening batch job (19:00) with increasing deadline slack:")
for deadline in (0.0, 4.0, 16.0):
    t = DeferrableTask(cpu=0.05, mem_mb=16, deadline_hours=deadline,
                       duration_hours=0.5)
    pl = sched.select(t, now_hour=19.0)
    print(f"  deadline {deadline:4.1f}h -> start {pl.start_hour % 24:5.1f}h on "
          f"{pl.node}, expected {pl.expected_carbon_g:.3f} g "
          f"(deferred {pl.deferred_hours:.1f}h)")
