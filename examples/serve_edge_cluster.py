"""End-to-end driver: serve a small LM with batched requests across a
simulated 3-region pod cluster with carbon-aware routing.

This is the paper's deployment story at pod scale: real JAX prefill/decode
(reduced qwen3 config on CPU), NSA routing per batch, roofline-derived
energy billing per step, and a mode comparison at the end.

Run:  PYTHONPATH=src python examples/serve_edge_cluster.py
"""
import jax
import numpy as np

from repro.configs.registry import reduced_config
from repro.core import costmodel, energy
from repro.core.router import GreenRouter, PodSpec
from repro.models import transformer
from repro.runtime.serving import Request, ServingEngine

PODS = [
    PodSpec("pod-high", chips=256, region="coal-heavy", carbon_intensity=620.0),
    PodSpec("pod-medium", chips=256, region="cn-average", carbon_intensity=530.0),
    PodSpec("pod-green", chips=256, region="hydro-rich", carbon_intensity=380.0),
]

cfg = reduced_config("qwen3-1.7b")
params = transformer.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

results = {}
for mode in ("performance", "green"):
    router = GreenRouter(PODS, mode=mode)
    flops = 2.0 * cfg.active_param_count() * 4
    hbm = costmodel.step_hbm_bytes(cfg, 32, 4, "decode")
    router.seed_profile({p.name: energy.roofline(flops, hbm, 0.0, 256)
                         for p in PODS})
    engine = ServingEngine(cfg, params, router, max_len=64, batch_size=4)
    for i in range(12):
        prompt = rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
        engine.submit(Request(uid=i, prompt=prompt, max_new_tokens=6))
    engine.run_all()
    rep = engine.report()
    results[mode] = rep
    pods_used = {r: a["tasks"] for r, a in rep["per_region"].items() if a["tasks"]}
    print(f"{mode:12s}: {rep['completed']} requests, "
          f"{rep['carbon_g_total']*1e3:.4f} mgCO2, pods={pods_used}")

red = 100 * (1 - results["green"]["carbon_g_total"]
             / results["performance"]["carbon_g_total"])
print(f"\ngreen vs performance carbon reduction: {red:.1f}% "
      f"(routing effect only; paper's edge setup: 22.9% vs monolithic)")
