"""Green partitioning + split execution across heterogeneous nodes.

Partitions MobileNetV2 (paper Eq. 5 cost model) across the three paper
nodes, then actually executes each segment with real JAX forward passes and
verifies the distributed result equals monolithic execution — the
correctness contract behind CarbonEdge's deployment.

Also shows the transformer generalisation: zamba2-2.7b's hybrid stack
partitioned into pipeline stages by per-block FLOPs.

Run:  PYTHONPATH=src python examples/partition_and_schedule.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cnn_zoo import get_cnn_config
from repro.configs.registry import get_config
from repro.core.cluster import PAPER_NODES
from repro.core.partitioner import (capacity_weights, green_weights,
                                    partition_cnn, partition_transformer)
from repro.models import cnn

# -- CNN: partition + split execution ---------------------------------------
cfg = get_cnn_config("mobilenetv2")
cpus = [n.cpu for n in PAPER_NODES]
intens = [n.carbon_intensity for n in PAPER_NODES]

for name, w in (("capacity", capacity_weights(cpus)),
                ("green", green_weights(cpus, intens))):
    part = partition_cnn(cfg, w, comm_weight=1e-9)
    shares = [c / sum(part.segment_costs) for c in part.segment_costs]
    print(f"{name:9s} weights {np.round(np.asarray(w)/np.sum(w), 3)} -> "
          f"segments {part.boundaries}, cost shares {np.round(shares, 3)}")

part = partition_cnn(cfg, green_weights(cpus, intens), comm_weight=1e-9)
params = cnn.init_params(cfg, jax.random.PRNGKey(0))
x = jnp.ones((1, 96, 96, 3))
y_mono = cnn.forward(cfg, params, x)
h = x
for (a, b), node in zip(part.segments(), PAPER_NODES):
    h = cnn.forward_range(cfg, params, h, a, b)
    print(f"  segment layers[{a}:{b}] on {node.name} "
          f"({node.carbon_intensity:.0f} gCO2/kWh) -> {tuple(h.shape)}")
err = float(jnp.max(jnp.abs(y_mono - h)))
print(f"distributed == monolithic: max err {err:.2e}\n")

# -- transformer: pipeline-stage assignment ----------------------------------
tcfg = get_config("zamba2-2.7b")
tpart = partition_transformer(tcfg, green_weights(cpus, intens),
                              seq=4096, batch=1, comm_weight=1e-12)
kinds = [ld.kind for ld in tcfg.layer_defs]
for (a, b), node in zip(tpart.segments(), PAPER_NODES):
    km = {k: kinds[a:b].count(k) for k in set(kinds[a:b])}
    print(f"zamba2 stage layers[{a}:{b}] on {node.name}: {km}")
