"""Joint partition+placement with a conformal carbon interval (DESIGN.md §8).

Where ``examples/partition_and_schedule.py`` splits a model across a *fixed*
node list, this example lets the scheduler choose the **(cut, node) pair**:
run layers [0, c) on the requesting device, offload layers [c, L) to the
best-scoring fleet node under the paper's Eq. 3 rule. The cut profile is
derived once per model (Eq. 5 costs + activation bytes for CNNs, per-block
FLOPs for transformers); cut 0 is full offload, so the joint decision can
only match or beat the cut-unaware scheduler.

The carbon estimate is then *interval-bounded*: a split-conformal calibrator
(forecast-vs-actual residuals over a held-out window) turns the point
forecast into a band with finite-sample >= 90% coverage, so the printed
estimate is "lo .. hi gCO2", not a single gamble on the forecast.

Run:  PYTHONPATH=src python examples/partitioned_inference.py
"""
import numpy as np

from repro.configs.cnn_zoo import get_cnn_config
from repro.configs.registry import get_config
from repro.core.api import ForecastProvider, TraceProvider
from repro.core.cluster import EdgeCluster, NodeSpec
from repro.core.scheduler import MODES, Task
from repro.core.temporal import synthetic_trace
from repro.partition import (PartitionPolicy, calibrate_intensity,
                             joint_time_energy, profile_cnn,
                             profile_transformer)

# -- heterogeneous fleet: the paper's three scenarios + two edge boxes ------
NODES = (
    NodeSpec("node-high", 1.0, 1024, 620.0, region="coal-heavy"),
    NodeSpec("node-medium", 0.6, 512, 530.0, region="cn-average"),
    NodeSpec("node-green", 0.4, 512, 380.0, region="hydro-rich"),
    NodeSpec("edge-pi", 0.25, 256, 120.0, power_w=6.5, region="solar-local"),
    NodeSpec("edge-nuc", 0.5, 512, 260.0, power_w=28.0, region="wind-mix"),
)
cluster = EdgeCluster(nodes=NODES)
cluster.profile(250.0)
task = Task(cpu=0.1, mem_mb=64.0, base_latency_ms=250.0)
NOW = 10.0  # 10:00 — mid-morning grid

# -- conformal band: calibrate the forecast against a noisy actual grid ----
actual = TraceProvider({n.name: synthetic_trace(n.region, n.carbon_intensity,
                                                noise=0.08, seed=i)
                        for i, n in enumerate(NODES)})
point = ForecastProvider(TraceProvider(
    {n.name: synthetic_trace(n.region, n.carbon_intensity)
     for n in NODES}), smoothing_hours=2.0)
names = [n.name for n in NODES]
cal_hours = np.arange(0.0, 24.0, 0.25)          # held-out calibration window
conf = calibrate_intensity(point, actual, names, cal_hours)
forecast = ForecastProvider(point.base, smoothing_hours=2.0, conformal=conf)
print(f"split-conformal 90% band: +/- {conf.quantile(0.9):.1f} gCO2/kWh "
      f"({conf.n} residuals)\n")

# -- joint (cut, node) decisions per model, green vs performance -----------
profiles = (profile_cnn(get_cnn_config("mobilenetv2"), batch=1),
            profile_transformer(get_config("zamba2-2.7b"), seq=512, batch=1))
for prof in profiles:
    print(f"{prof.name}: {prof.num_cuts} candidate cuts")
    for mode in ("green", "performance"):
        policy = PartitionPolicy(prof, backend="numpy")
        d = policy.decide(cluster, task, MODES[mode], forecast, NOW)
        st = cluster.nodes[d.node]
        t_s, e_kwh = joint_time_energy(st.avg_time_ms / 1000.0,
                                       st.power_w(cluster.host_power_w),
                                       d.remote_frac, d.comm_s)
        lo_i, hi_i = forecast.intensity_interval_batch([d.node], NOW)
        lo_g, hi_g = float(lo_i[0]) * e_kwh, float(hi_i[0]) * e_kwh
        split = (f"layers [0:{d.cut}) local + [{d.cut}:L) remote"
                 if d.cut else "full offload")
        print(f"  {mode:12s} -> {d.node:12s} cut {d.cut:3d} ({split}), "
              f"{d.remote_frac:.0%} remote, uplink {d.comm_s * 1e3:.1f} ms")
        print(f"  {'':12s}    est {t_s * 1e3:.0f} ms, carbon "
              f"{lo_g * 1e3:.3f} .. {hi_g * 1e3:.3f} mgCO2 (90% band)")
    print()

# -- end-to-end: the engine executes and bills only the offloaded segment --
from repro.core.api import CarbonEdgeEngine  # noqa: E402

policy = PartitionPolicy(profiles[0], backend="numpy")
eng = CarbonEdgeEngine(cluster, mode="green", policy=policy,
                       provider=forecast)
res = eng.submit_many([task] * 8).step(now_hour=NOW)
d = policy.last_decisions[0]
print(f"engine.step: {len(res)} tasks on {d.node}, billed "
      f"{res[0].latency_ms:.0f} ms each (offloaded segment of "
      f"{task.base_latency_ms:.0f} ms base); fleet total "
      f"{eng.monitor.total_carbon_g() * 1e3:.3f} mgCO2")
