"""Chaos drill: closed-loop tenants through a node crash + feed blackout.

A scripted fault scenario (DESIGN.md §10) hits a two-tenant closed-loop
deployment: the greenest node crashes with a detection lag (the
scheduler keeps placing onto it until it is caught by contact or by the
detector), then the carbon feed blacks out (reads degrade to
last-known-good values with staleness-widened intervals), then both
recover. The run keeps serving throughout — contact failures fail over
via one batched re-selection, hopeless tasks dead-letter after the
retry cap instead of looping — and the decision trace can explain a
failover placement after the fact.

Run:  PYTHONPATH=src python examples/chaos_serving.py
"""
from repro.core.api import CarbonEdgeEngine, StaticProvider
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.obs import Observability
from repro.resilience import (Fault, FaultInjector, Resilience,
                              ResilientProvider)
from repro.sim import (AsyncEngineDriver, ClientPopulation,
                       ClosedLoopClientPool)
from repro.tenancy import TenantPolicy, TenantRegistry, TenantSpec
from repro.tenancy.spec import TenantTask

BASE_MS = 250.0

# -- the script: crash (lagged detection) -> blackout -> full recovery ------
FAULTS = [
    Fault(0.004, "crash", "node-green", detected=False),  # ground truth only
    Fault(0.008, "detect", "node-green"),                 # detector catches up
    Fault(0.010, "blackout"),                             # carbon feed dark
    Fault(0.016, "restore"),
    Fault(0.020, "recover", "node-green"),
]

cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
cluster.profile(BASE_MS)
provider = ResilientProvider(StaticProvider(
    {n: cluster.nodes[n].spec.carbon_intensity for n in cluster.nodes}))
registry = TenantRegistry([TenantSpec("gold", mode="green", priority=2),
                           TenantSpec("batch", mode="green")])
res = Resilience(max_attempts=3, backoff_base_hours=0.002)
obs = Observability.all()
engine = CarbonEdgeEngine(cluster, mode="green",
                          policy=TenantPolicy(registry=registry),
                          provider=provider, resilience=res, obs=obs)

pool = ClosedLoopClientPool(
    [ClientPopulation("gold", 6, mean_think_hours=0.0008,
                      slo_latency_s=2.0, priority=2),
     ClientPopulation("batch", 4, mean_think_hours=0.002,
                      slo_latency_s=10.0)],
    seed=4)
driver = AsyncEngineDriver(
    engine, None,
    lambda uid, hour, tenant: TenantTask(cpu=0.05, mem_mb=16.0,
                                         base_latency_ms=BASE_MS,
                                         tenant=tenant),
    horizon_hours=0.03, max_batch=8, slo_latency_s=5.0, clients=pool,
    faults=FaultInjector.scripted(FAULTS))
metrics = driver.run()

# -- phase-by-phase: where did the work land? -------------------------------
PHASES = [("healthy", 0.0, 0.004), ("crash undetected", 0.004, 0.008),
          ("crash detected", 0.008, 0.020), ("recovered", 0.020, 0.03)]
print("placements per phase (node-green is the crashed node):")
for label, lo, hi in PHASES:
    recs = [r for r in metrics.records if lo <= r.start_hour < hi]
    on_green = sum(1 for r in recs if r.node == "node-green")
    print(f"  {label:16s} tasks={len(recs):3d}  on node-green={on_green:3d}")

# -- the failover, explained from the trace ---------------------------------
crash, recover = FAULTS[0].hour, FAULTS[-1].hour
fail_row = next((r for r in obs.trace.rows()
                 if crash <= r["hour"] < recover and r["verdict"] == "done"
                 and r["node"] != "node-green"), None)
if fail_row is not None:
    print("\none failover decision, explained:")
    print(" ", obs.trace.explain(fail_row["step"], fail_row["task"]))
print("verdicts:", obs.trace.verdict_counts())

# -- degraded-mode + recovery accounting ------------------------------------
rep = engine.report()
print("\nresilience report:", rep["resilience"])
print(f"provider reads served stale during the blackout: "
      f"{provider.served_stale}")
print(f"dead-letters: {len(engine.dead_letters)} "
      f"(sim counted: {dict(metrics.dead) or 0})")
inj = FaultInjector.scripted(FAULTS)
print(f"schedule MTTR: {inj.mttr_hours() * 60:.1f} min "
      f"(one crash window of {(recover - crash) * 60:.1f} min)")
s = metrics.summary()
print(f"\nserved {s['tasks']} requests through the drill: "
      f"p95 latency {s['latency_s_p95']:.2f} s, "
      f"SLO violation rate {s['slo_violation_rate']:.3f}, "
      f"{s['carbon_g_per_task'] * 1e3:.3f} mg CO2/task")
