"""Quickstart: CarbonEdge's three mechanisms in ~70 lines.

1. schedule with the carbon-aware NSA through the CarbonEdgeEngine
   (paper Eq. 3/4, Table I modes; DESIGN.md policy/provider API);
2. partition a model with the green partitioner (paper Eq. 5);
3. account energy/carbon with the Carbon Monitor (paper Eq. 1/2).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.cnn_zoo import get_cnn_config
from repro.core.api import CarbonEdgeEngine, StaticProvider
from repro.core.carbon import CarbonMonitor
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.partitioner import green_weights, partition_cnn
from repro.core.scheduler import MODES, Task, score_table

# -- 1. carbon-aware scheduling (engine facade) ------------------------------
cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
cluster.profile(base_latency_ms=254.85)           # seed per-node history
task = Task(cpu=0.1, mem_mb=64, base_latency_ms=254.85)

print("score components [S_R S_L S_P S_B S_C]:")
for node, s in score_table(cluster, task).items():
    print(f"  {node:12s} {np.round(s, 3)}")

# grid intensity flows through a provider; scheduling through a policy —
# the engine defaults to the batched vectorized/Pallas path.
provider = StaticProvider.from_cluster(cluster)
for mode in MODES:
    engine = CarbonEdgeEngine(
        EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0), mode=mode,
        provider=provider)
    engine.cluster.profile(254.85)
    rep = engine.run(task=task, iterations=10)
    top = max(rep["distribution"], key=rep["distribution"].get)
    print(f"{mode:12s} -> {top}  "
          f"({rep['totals']['carbon_g_per_inf']*1e3:.2f} mgCO2/inf, "
          f"policy={rep['policy']})")

# -- 2. green partitioning ---------------------------------------------------
cfg = get_cnn_config("mobilenetv2")
cpus = [n.cpu for n in PAPER_NODES]
intensities = [n.carbon_intensity for n in PAPER_NODES]
part = partition_cnn(cfg, green_weights(cpus, intensities), comm_weight=1e-9)
print(f"\nmobilenetv2 partitioned into {part.num_segments} segments "
      f"at layer boundaries {part.boundaries}")
print(f"segment costs (Eq.5): {[f'{c:.2e}' for c in part.segment_costs]}")

# -- 3. carbon accounting ----------------------------------------------------
monitor = CarbonMonitor()
monitor.register_region("hydro-rich", intensity=380.0)
carbon = monitor.record_power_sample("hydro-rich", dt_s=0.272, p_cpu_w=142.0)
print(f"\none inference on the green node: {carbon:.5f} gCO2 "
      f"(paper Table II green: 0.0041)")
